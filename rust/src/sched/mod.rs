//! L3 coordinator — the paper's contribution: a libgomp-like
//! loop-scheduling runtime with pluggable self-scheduling policies.
//!
//! Entry point: [`parallel_for`] — schedule `n` loop iterations over
//! `p` worker threads under a [`Policy`]. Bodies receive iteration
//! *ranges* so per-chunk dispatch overhead is amortized exactly the way
//! an OpenMP runtime amortizes it. [`parallel_for_async`] is the
//! non-blocking variant for serving layers: it enqueues the loop as an
//! epoch on the persistent pool and returns a [`LoopJoin`] handle, so
//! independent loops from different submitters overlap instead of
//! serializing.
//!
//! Policies (paper Table 2 plus related-work extensions):
//! `static`, `dynamic,c`, `guided,c`, `taskloop`, `factoring`,
//! `binlpt,k` (workload-aware), `stealing,c` (fixed-chunk THE
//! work-stealing), **`ich,ε` (the paper's method)**, `awf`, `hss`.
//!
//! # Execution layer
//!
//! Engines do not spawn threads themselves: each one hands its worker
//! function to an [`Executor`] (`exec.run(p, f)` runs `f(tid)` exactly
//! once per `tid in 0..p` and joins; `exec.run_async` does the same
//! without blocking the submitter). Executors:
//!
//! - [`runtime::Runtime`] — the default: a **persistent, core-pinned
//!   worker pool**, spawned once per process and reused across
//!   `parallel_for` calls. Epochs from any number of submitters queue
//!   FIFO on the pool (blocking callers participate as tid 0; async
//!   callers get a join handle), so concurrent and back-to-back loops
//!   share the amortized workers instead of degrading to per-call
//!   spawning. Nested `parallel_for` calls from inside a body, and
//!   calls asking for more threads than the pool holds, still fall
//!   back to scoped spawning — no deadlock, only degraded
//!   amortization. See `sched::runtime` for the epoch protocol and
//!   the heap-epoch safety argument.
//! - [`SpawnExec`] — per-call scoped spawn + join (the seed behavior),
//!   selectable with [`ExecMode::Spawn`] for measurement baselines.
//! - Single-thread runs (`threads == 1`) execute inline on the caller
//!   with no spawning and **no affinity changes**.
//!
//! [`ForOpts::mode`] picks the executor; the fork-join overhead gap is
//! measured by `benches/bench_overhead.rs` (`BENCH_forkjoin.json`),
//! and blocking vs async submission by the same bench's
//! `BENCH_async.json`.
//!
//! [`ForOpts::victim`] picks the steal-victim policy of the
//! work-stealing engines: uniform random (paper §3.3), two-tier
//! topology-biased, or distance-*ranked* multi-tier selection over
//! the core→NUMA-node map and node-distance matrix discovered by
//! [`topology::Topology::detect`] (`BENCH_numa.json` measures the
//! two-tier local-steal fraction and wall-time effect per engine;
//! `BENCH_distance.json` compares uniform vs topo vs ranked on a
//! ≥2-node distance topology). The same matrix weights the pool's
//! within-class EDF dispatch key, so near-deadline epochs land on
//! workers that won't pay cross-socket traffic (see `sched::dispatch`
//! and `sched::runtime`).
//!
//! [`ForOpts::class`] / [`ForOpts::deadline`] pick the **dispatch
//! class** of the submission on the pool's multi-class epoch queue:
//! `Interactive` > `Batch` (default) > `Background`, EDF within a
//! class, bounded anti-starvation promotion across classes, and
//! chunk-granular preemption (engines poll
//! [`runtime::preempt_point`] between chunk claims, so a newly
//! arrived `Interactive` loop pulls workers out of a running
//! `Background` loop without aborting chunks). See `sched::dispatch`
//! for the exact ordering rule and `sched::runtime` for how it is
//! enforced; `BENCH_priority.json` measures the Interactive queue-wait
//! win under saturating Background load.

pub mod assist;
pub mod auto;
pub mod binlpt;
pub mod central;
pub mod deque;
pub mod dispatch;
pub mod engine;
pub mod fair;
pub mod features;
pub mod metrics;
pub mod policy;
pub mod pool;
pub mod related;
pub mod runtime;
pub mod topology;
pub mod ws;

pub use dispatch::{DispatchQueue, LatencyClass, PopInfo, CLASSES, PROMOTE_K};
pub use engine::{Engine, LoopReq};
pub use fair::{
    Admission, ChargeMode, FairJob, FairQueue, FairShare, FairTenantStats, FairTicket, RejectReason, TenantSpec,
    TokenBucket, WEIGHT_UNIT,
};
pub use metrics::{MetricsSink, RunMetrics};
pub use runtime::{preempt_point, ClassStats, DispatchInfo, Executor, LoopHandle, Runtime, SpawnExec, SubmitOpts};
pub use topology::{Topology, VictimPolicy};
pub use ws::{IchParams, StealMerge};

use std::ops::Range;
use std::sync::Arc;

/// A self-scheduling policy with its tuning parameters (paper Table 2).
#[derive(Clone, Debug)]
pub enum Policy {
    /// Even block partition, no runtime scheduling.
    Static,
    /// OpenMP `schedule(dynamic, chunk)`.
    Dynamic { chunk: usize },
    /// OpenMP `schedule(guided, chunk)` (chunk = minimum).
    Guided { chunk: usize },
    /// OpenMP `taskloop num_tasks(t)`; `0` means `num_threads`.
    Taskloop { num_tasks: usize },
    /// Factoring Self-Scheduling with batch factor `alpha` (≈2).
    Factoring { alpha: f64 },
    /// BinLPT with at most `max_chunks` chunks (needs `weights`).
    Binlpt { max_chunks: usize },
    /// Fixed-chunk THE work-stealing (the paper's base algorithm).
    Stealing { chunk: usize },
    /// iCh — the paper's adaptive-chunk work-stealing (§3).
    Ich(IchParams),
    /// Adaptive Weighted Factoring (related work, §4).
    Awf,
    /// History-aware static partition (HSS-lite, related work, §4).
    Hss,
    /// Online per-loop-site engine selection (`sched::auto`): a
    /// seeded deterministic bandit over [`auto::arms`] that learns
    /// the best fixed engine per (callsite, trip-count bucket,
    /// feature bucket) from observed run costs. Knobs:
    /// `ICH_AUTO_SEED`, `ICH_AUTO_EXPLORE`.
    Auto,
}

impl Policy {
    /// Canonical short name used by the CLI and result files.
    pub fn name(&self) -> String {
        match self {
            Policy::Static => "static".into(),
            Policy::Dynamic { chunk } => format!("dynamic,{chunk}"),
            Policy::Guided { chunk } => format!("guided,{chunk}"),
            Policy::Taskloop { num_tasks } => format!("taskloop,{num_tasks}"),
            Policy::Factoring { alpha } => format!("factoring,{alpha}"),
            Policy::Binlpt { max_chunks } => format!("binlpt,{max_chunks}"),
            Policy::Stealing { chunk } => format!("stealing,{chunk}"),
            Policy::Ich(p) => format!("ich,{}", p.eps),
            Policy::Awf => "awf".into(),
            Policy::Hss => "hss".into(),
            Policy::Auto => "auto".into(),
        }
    }

    /// Family name without parameters ("dynamic", "ich", ...).
    pub fn family(&self) -> &'static str {
        match self {
            Policy::Static => "static",
            Policy::Dynamic { .. } => "dynamic",
            Policy::Guided { .. } => "guided",
            Policy::Taskloop { .. } => "taskloop",
            Policy::Factoring { .. } => "factoring",
            Policy::Binlpt { .. } => "binlpt",
            Policy::Stealing { .. } => "stealing",
            Policy::Ich(_) => "ich",
            Policy::Awf => "awf",
            Policy::Hss => "hss",
            Policy::Auto => "auto",
        }
    }

    /// Parse "family,param" strings, e.g. "ich,0.33" or "dynamic,2".
    pub fn parse(s: &str) -> Option<Policy> {
        let (fam, arg) = match s.split_once(',') {
            Some((f, a)) => (f, Some(a)),
            None => (s, None),
        };
        fn num<T: std::str::FromStr>(arg: Option<&str>, default: T) -> Option<T> {
            match arg {
                None => Some(default),
                Some(a) => a.parse().ok(),
            }
        }
        Some(match fam {
            "static" => Policy::Static,
            "dynamic" => Policy::Dynamic { chunk: num(arg, 1)? },
            "guided" => Policy::Guided { chunk: num(arg, 1)? },
            "taskloop" => Policy::Taskloop { num_tasks: num(arg, 0)? },
            "factoring" => Policy::Factoring { alpha: num(arg, 2.0)? },
            "binlpt" => Policy::Binlpt { max_chunks: num(arg, 384)? },
            "stealing" => Policy::Stealing { chunk: num(arg, 1)? },
            "ich" => Policy::Ich(IchParams::with_eps(num(arg, 0.33)?)),
            "awf" => Policy::Awf,
            "hss" => Policy::Hss,
            "auto" => Policy::Auto,
            _ => return None,
        })
    }

    /// Does this policy require per-iteration workload estimates?
    pub fn needs_weights(&self) -> bool {
        matches!(self, Policy::Binlpt { .. } | Policy::Hss)
    }

    /// One representative configuration per family — the canonical
    /// all-families list shared by the coverage tests, the pool stress
    /// suite, and the fork-join benchmark, so the three cannot drift.
    pub fn representatives() -> Vec<Policy> {
        vec![
            Policy::Static,
            Policy::Dynamic { chunk: 64 },
            Policy::Guided { chunk: 1 },
            Policy::Taskloop { num_tasks: 0 },
            Policy::Factoring { alpha: 2.0 },
            Policy::Binlpt { max_chunks: 64 },
            Policy::Stealing { chunk: 64 },
            Policy::Ich(IchParams::default()),
            Policy::Awf,
            Policy::Hss,
            Policy::Auto,
        ]
    }

    /// Process-wide default policy: CLI `--policy` / `ICH_POLICY`
    /// env, else the paper's `ich,0.33`. Resolved once; embedders and
    /// CLI paths that want "whatever the process was told to run"
    /// read this instead of hard-coding a family.
    pub fn process_default() -> Policy {
        policy_default_cell()
            .get_or_init(|| {
                std::env::var("ICH_POLICY")
                    .ok()
                    .and_then(|s| Policy::parse(s.trim()))
                    .unwrap_or(Policy::Ich(IchParams::default()))
            })
            .clone()
    }

    /// Install the process default before first use (the CLI's
    /// `--policy` flag). First caller wins; returns whether this call
    /// set it.
    pub fn set_process_default(p: Policy) -> bool {
        policy_default_cell().set(p).is_ok()
    }
}

fn policy_default_cell() -> &'static std::sync::OnceLock<Policy> {
    static DEFAULT: std::sync::OnceLock<Policy> = std::sync::OnceLock::new();
    &DEFAULT
}

/// How `parallel_for` obtains its worker threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// The shared persistent worker pool ([`Runtime::global`]).
    /// Epochs queue FIFO when the pool is busy; runs wider than the
    /// pool, and nested calls from pool workers, fall back to scoped
    /// spawning.
    #[default]
    Pool,
    /// Spawn and join fresh OS threads for this call (the seed
    /// runtime's strategy; also what the pool falls back to).
    Spawn,
}

/// Options for a `parallel_for` run.
#[derive(Clone, Debug)]
pub struct ForOpts<'a> {
    /// Worker thread count p.
    pub threads: usize,
    /// Pin threads to cores when the host has enough of them
    /// (OMP_PROC_BIND=true analog). Pool workers pin once at spawn,
    /// so this flag only governs [`ExecMode::Spawn`] runs with
    /// `threads > 1` (the pool's internal fallbacks, async teams, and
    /// single-thread runs never re-pin the calling thread).
    pub pin: bool,
    /// RNG seed for steal-victim selection (reproducibility).
    pub seed: u64,
    /// Per-iteration workload estimates — consumed only by
    /// workload-aware policies (BinLPT, HSS). Must have length `n`.
    pub weights: Option<&'a [f64]>,
    /// Worker-thread provider (persistent pool by default).
    pub mode: ExecMode,
    /// Steal-victim selection for the work-stealing engines
    /// (`stealing`, `ich`): uniform random (the paper's rule),
    /// two-tier topology-biased, or distance-ranked multi-tier over
    /// the node-distance matrix. The default comes from
    /// [`VictimPolicy::process_default`] (CLI `--steal` / `ICH_STEAL`
    /// env, else `Topo`); both biased modes degrade to exact uniform
    /// selection on single-node (for `Ranked`, also all-equidistant)
    /// topologies.
    pub victim: VictimPolicy,
    /// Dispatch class on the pool's multi-class epoch queue. The
    /// default comes from [`LatencyClass::process_default`] (CLI
    /// `--class` / `ICH_CLASS` env, else `Batch` — all-default
    /// traffic keeps the exact classless FIFO order).
    pub class: LatencyClass,
    /// Absolute virtual-tick deadline for EDF ordering within the
    /// class (`None` = no deadline, sorts after every deadline).
    pub deadline: Option<u64>,
    /// Work assisting: publish this run's epoch on the pool's assist
    /// board so idle workers join it mid-flight, and let the blocking
    /// submitter execute chunks of its own epoch instead of spinning.
    /// The default comes from [`assist::process_default`] (CLI
    /// `--assist` / `ICH_ASSIST` env, else off — the off-path is
    /// byte-identical to the pre-assist runtime).
    pub assist: bool,
    /// Tenant index for multi-tenant attribution (see `sched::fair`);
    /// rides the epoch into [`DispatchInfo`] and [`RunMetrics`].
    /// `None` = untenanted traffic, byte-identical to before.
    pub tenant: Option<u32>,
    /// Loop-site identity override for the [`Policy::Auto`] selector.
    /// `None` (default) derives the site from the submitting callsite
    /// (`#[track_caller]`) plus a log₂ trip-count bucket — right for
    /// loops written in source. Embedders that funnel many distinct
    /// loops through one shared submission point (a job queue, the
    /// fair front end) can install stable per-loop ids here so the
    /// selector learns them separately.
    pub site: Option<u64>,
}

impl Default for ForOpts<'_> {
    fn default() -> Self {
        ForOpts {
            threads: 1,
            pin: true,
            seed: 0x1C4,
            weights: None,
            mode: ExecMode::Pool,
            victim: VictimPolicy::process_default(),
            class: LatencyClass::process_default(),
            deadline: None,
            assist: assist::process_default(),
            tenant: None,
            site: None,
        }
    }
}

impl<'a> ForOpts<'a> {
    pub fn threads(p: usize) -> Self {
        ForOpts { threads: p, ..Default::default() }
    }

    pub fn with_weights(mut self, w: &'a [f64]) -> Self {
        self.weights = Some(w);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_mode(mut self, mode: ExecMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn with_victim(mut self, victim: VictimPolicy) -> Self {
        self.victim = victim;
        self
    }

    pub fn with_class(mut self, class: LatencyClass) -> Self {
        self.class = class;
        self
    }

    pub fn with_deadline(mut self, deadline: u64) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_assist(mut self, assist: bool) -> Self {
        self.assist = assist;
        self
    }

    pub fn with_tenant(mut self, tenant: u32) -> Self {
        self.tenant = Some(tenant);
        self
    }

    pub fn with_site(mut self, site: u64) -> Self {
        self.site = Some(site);
        self
    }

    /// The [`SubmitOpts`] this run hands the pool. The submission
    /// origin is left to auto-detection (the submitting thread's
    /// pinned core, if any).
    fn submit_opts(&self) -> SubmitOpts {
        SubmitOpts {
            class: self.class,
            deadline: self.deadline,
            pin_fallback: self.pin,
            origin: None,
            assist: self.assist,
            tenant: self.tenant,
        }
    }
}

/// Degenerate executor for single-thread runs: the body executes
/// inline on the caller with no spawning and — unlike
/// `scoped_run(1, true, …)` — no affinity changes. (A default-opts
/// `threads == 1` run used to route through the scoped spawner and
/// permanently pin the *calling* thread to core 0.)
pub(crate) struct InlineExec;

impl Executor for InlineExec {
    fn run(&self, p: usize, f: &(dyn Fn(usize) + Sync)) {
        for tid in 0..p {
            f(tid);
        }
    }
}

/// Dispatch one parallel region through the engine registry
/// (`sched::engine`). Shared by the blocking and async entry points
/// so the two cannot drift. Fixed policies go straight to their
/// engine; [`Policy::Auto`] asks the selector (`sched::auto`) for an
/// arm, runs it, and feeds the observed cost and workload features
/// back so the next dispatch at this loop site chooses better.
#[allow(clippy::too_many_arguments)]
fn run_policy(
    n: usize,
    policy: &Policy,
    p: usize,
    weights: Option<&[f64]>,
    seed: u64,
    victim: VictimPolicy,
    callsite: u64,
    auto_tbl: &auto::AutoTable,
    exec: &dyn Executor,
    body: &(dyn Fn(Range<usize>) + Sync),
    sink: &MetricsSink,
) {
    let req = engine::LoopReq { n, p, weights, seed, victim };
    if matches!(policy, Policy::Auto) {
        let arms = auto::arms();
        let cfg = auto::AutoConfig::process_default();
        let cold = auto::cold_hint(arms, n, p, weights.is_some());
        let site = features::site_key(callsite, n);
        let choice = auto_tbl.choose(site, &cfg, arms.len(), cold);
        sink.set_auto_arm(choice.arm);
        let t0 = std::time::Instant::now();
        engine::run_fixed(&arms[choice.arm], &req, exec, body, sink);
        let elapsed = t0.elapsed();
        // Per-iteration cost in ns — the argmin is scale-free, but
        // per-iteration normalization keeps one site's statistics
        // comparable across its ±2× trip-count bucket.
        let per_iter = elapsed.as_secs_f64() * 1e9 / n.max(1) as f64;
        auto_tbl.observe(&choice, auto::quantize(per_iter));
        let feats = features::FeatureVec::extract(&sink.collect(elapsed), n, p);
        auto_tbl.note_bucket(site, feats.bucket());
        return;
    }
    engine::run_fixed(policy, &req, exec, body, sink)
}

/// Schedule `n` iterations over the configured threads; `body`
/// receives disjoint iteration ranges covering `0..n` exactly once.
/// Returns timing + scheduling metrics.
///
/// `#[track_caller]`: the invoking source location identifies the
/// loop site for the [`Policy::Auto`] selector (override with
/// [`ForOpts::with_site`]).
#[track_caller]
pub fn parallel_for(n: usize, policy: &Policy, opts: &ForOpts, body: &(dyn Fn(Range<usize>) + Sync)) -> RunMetrics {
    let loc = std::panic::Location::caller();
    let callsite = opts.site.unwrap_or_else(|| features::callsite_hash(loc));
    let p = opts.threads.max(1);
    let sink = MetricsSink::new(p);
    // `start` is taken only once the executor exists, so the first
    // pool-mode call in a process does not charge the one-time lazy
    // global-pool spawn to its own elapsed_s.
    let start;
    let dispatch = if p == 1 {
        // p == 1 runs inline in every mode; don't spawn the global
        // pool — or touch the caller's affinity — for callers that
        // never fan out. Selector state lives in the process table
        // (no pool exists to own one).
        let tbl = auto::process_table();
        start = std::time::Instant::now();
        run_policy(n, policy, p, opts.weights, opts.seed, opts.victim, callsite, tbl, &InlineExec, body, &sink);
        None
    } else if opts.mode == ExecMode::Spawn {
        let spawn = SpawnExec::new(opts.pin);
        let tbl = auto::process_table();
        start = std::time::Instant::now();
        run_policy(n, policy, p, opts.weights, opts.seed, opts.victim, callsite, tbl, &spawn, body, &sink);
        None
    } else {
        let rt = Runtime::global();
        let pool = rt.executor_with(opts.submit_opts());
        start = std::time::Instant::now();
        run_policy(n, policy, p, opts.weights, opts.seed, opts.victim, callsite, rt.auto_table(), &pool, body, &sink);
        pool.take_report()
    };
    let mut m = sink.collect(start.elapsed());
    m.class = opts.class;
    m.edf_tick_scale = topology::edf_tick_scale();
    if let Some(d) = dispatch {
        m.queue_wait_s = d.queue_wait_s;
        m.promoted = d.promoted;
        m.dispatch_skips = d.skips;
        m.tenant = d.tenant;
    }
    m
}

/// Join handle of an asynchronously submitted `parallel_for`.
///
/// Returned by [`parallel_for_async`]; [`LoopJoin::join`] blocks until
/// the loop completes, rethrows worker panics on the joining thread,
/// and returns the run's [`RunMetrics`]. The metrics' `elapsed_s`
/// spans submission to join-observed completion, so it includes any
/// time the epoch spent queued behind other epochs.
pub struct LoopJoin {
    handle: LoopHandle,
    sink: Arc<MetricsSink>,
    start: std::time::Instant,
    class: LatencyClass,
}

impl LoopJoin {
    /// Has the loop finished? (Non-blocking.)
    pub fn is_finished(&self) -> bool {
        self.handle.is_finished()
    }

    /// Wait for the loop, rethrow any worker panic, return its metrics
    /// (including the dispatch class, queue wait, and promotion state
    /// when the loop ran as a pool epoch).
    pub fn join(self) -> RunMetrics {
        let dispatch = self.handle.join_with_dispatch();
        let mut m = self.sink.collect(self.start.elapsed());
        m.class = self.class;
        m.edf_tick_scale = topology::edf_tick_scale();
        if let Some(d) = dispatch {
            m.queue_wait_s = d.queue_wait_s;
            m.promoted = d.promoted;
            m.dispatch_skips = d.skips;
            m.tenant = d.tenant;
        }
        m
    }
}

/// Asynchronous [`parallel_for`] on the global pool: enqueue the loop
/// as an epoch and return immediately with a [`LoopJoin`]. All `p`
/// scheduler tids run on pool workers (the submitter does not
/// participate), so independent loops submitted from different
/// threads — or several loops from one thread — execute overlapped.
///
/// The body must be shareable and `'static` (`Arc`) because the
/// submitter's frame no longer bounds the epoch's lifetime; `weights`
/// are copied out of `opts` for the same reason.
#[track_caller]
pub fn parallel_for_async(
    n: usize,
    policy: &Policy,
    opts: &ForOpts,
    body: Arc<dyn Fn(Range<usize>) + Send + Sync>,
) -> LoopJoin {
    parallel_for_async_on(Runtime::global(), n, policy, opts, body)
}

/// [`parallel_for_async`] against an explicit pool — embedders and
/// tests can target private [`Runtime`]s. `opts.mode == Spawn` runs
/// the whole loop on a detached per-call thread team instead.
#[track_caller]
pub fn parallel_for_async_on(
    rt: &Runtime,
    n: usize,
    policy: &Policy,
    opts: &ForOpts,
    body: Arc<dyn Fn(Range<usize>) + Send + Sync>,
) -> LoopJoin {
    let loc = std::panic::Location::caller();
    let callsite = opts.site.unwrap_or_else(|| features::callsite_hash(loc));
    let p = opts.threads.max(1);
    let sink = Arc::new(MetricsSink::new(p));
    let policy = policy.clone();
    let weights: Option<Vec<f64>> = opts.weights.map(|w| w.to_vec());
    let seed = opts.seed;
    let victim = opts.victim;
    let sink2 = Arc::clone(&sink);
    // The driver outlives this frame, so it carries a shared handle
    // to the selector table of the pool it will run on (detached
    // Spawn teams learn into the process table).
    let auto_tbl: Arc<auto::AutoTable> = match opts.mode {
        ExecMode::Pool => rt.auto_table_shared(),
        ExecMode::Spawn => auto::process_table_shared(),
    };
    let start = std::time::Instant::now();
    let driver: Box<dyn FnOnce(&dyn Executor) + Send> = Box::new(move |exec: &dyn Executor| {
        let b = |r: Range<usize>| body(r);
        run_policy(n, &policy, p, weights.as_deref(), seed, victim, callsite, &auto_tbl, exec, &b, &sink2);
    });
    let handle = match opts.mode {
        ExecMode::Pool => rt.submit_driver_with(p, driver, opts.submit_opts()),
        // Spawn mode honors the per-run pin the same way blocking
        // Spawn runs do: the teams' spawned members pin round-robin.
        ExecMode::Spawn => runtime::detach_driver(driver, opts.pin),
    };
    LoopJoin { handle, sink, start, class: opts.class }
}

/// Convenience: per-iteration body.
#[track_caller]
pub fn parallel_for_each(n: usize, policy: &Policy, opts: &ForOpts, f: &(dyn Fn(usize) + Sync)) -> RunMetrics {
    parallel_for(n, policy, opts, &|r: Range<usize>| {
        for i in r {
            f(i)
        }
    })
}

/// The paper's Table 2 parameter grid for a policy family, used by the
/// harness's best-over-params reporting (§6.1).
pub fn table2_grid(family: &str) -> Vec<Policy> {
    match family {
        "static" => vec![Policy::Static],
        "dynamic" => [1, 2, 3].iter().map(|&c| Policy::Dynamic { chunk: c }).collect(),
        "guided" => [1, 2, 3].iter().map(|&c| Policy::Guided { chunk: c }).collect(),
        "taskloop" => vec![Policy::Taskloop { num_tasks: 0 }],
        "factoring" => vec![Policy::Factoring { alpha: 2.0 }],
        "binlpt" => [128, 384, 576].iter().map(|&k| Policy::Binlpt { max_chunks: k }).collect(),
        "stealing" => [1, 2, 3, 64].iter().map(|&c| Policy::Stealing { chunk: c }).collect(),
        "ich" => [0.25, 0.33, 0.50].iter().map(|&e| Policy::Ich(IchParams::with_eps(e))).collect(),
        "awf" => vec![Policy::Awf],
        "hss" => vec![Policy::Hss],
        _ => vec![],
    }
}

/// The scheduler families the paper's figures compare.
pub const PAPER_FAMILIES: &[&str] = &["guided", "dynamic", "taskloop", "binlpt", "stealing", "ich"];

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering::SeqCst};

    #[test]
    fn every_policy_covers_exactly_once() {
        let n = 500;
        // Representatives (chunk 64: few, large dispatches) plus
        // deliberately tiny chunks — hundreds of dispatches per run —
        // so the exactly-once invariant is exercised under heavy
        // steal/claim contention on both executors.
        let mut policies = Policy::representatives();
        policies.extend([
            Policy::Dynamic { chunk: 2 },
            Policy::Stealing { chunk: 2 },
            Policy::Binlpt { max_chunks: 16 },
            Policy::Guided { chunk: 2 },
        ]);
        for mode in [ExecMode::Pool, ExecMode::Spawn] {
            for policy in &policies {
                let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                let w: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
                let opts = ForOpts { threads: 4, pin: false, seed: 1, weights: Some(&w), mode, ..Default::default() };
                let m = parallel_for(n, policy, &opts, &|r| {
                    for i in r {
                        hits[i].fetch_add(1, SeqCst);
                    }
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(SeqCst), 1, "policy {} mode {mode:?} iter {i}", policy.name());
                }
                assert_eq!(m.total_iters, n as u64, "policy {}", policy.name());
            }
        }
    }

    #[test]
    fn every_policy_covers_exactly_once_async() {
        let n = 400;
        for policy in Policy::representatives() {
            let hits: Arc<Vec<AtomicU64>> = Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
            let w: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
            let opts = ForOpts { threads: 3, pin: false, seed: 2, weights: Some(&w), ..Default::default() };
            let h2 = Arc::clone(&hits);
            let join = parallel_for_async(n, &policy, &opts, Arc::new(move |r: std::ops::Range<usize>| {
                for i in r {
                    h2[i].fetch_add(1, SeqCst);
                }
            }));
            let m = join.join();
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(SeqCst), 1, "policy {} iter {i}", policy.name());
            }
            assert_eq!(m.total_iters, n as u64, "policy {}", policy.name());
        }
    }

    #[test]
    fn parse_round_trips() {
        // Property over every representative — including `factoring`
        // and the defaults: parse(name()) must reproduce name().
        for p in Policy::representatives() {
            let s = p.name();
            let q = Policy::parse(&s).unwrap_or_else(|| panic!("parse failed for {s}"));
            assert_eq!(q.name(), s, "parse/name round trip for {s}");
        }
        // Non-default parameters and junk.
        for s in ["dynamic,2", "guided,3", "taskloop,16", "binlpt,384", "stealing,64", "ich,0.25", "factoring,1.5"] {
            assert_eq!(Policy::parse(s).unwrap().name(), s, "parse/name mismatch for {s}");
        }
        assert!(Policy::parse("nonsense").is_none());
    }

    #[test]
    fn parse_defaults() {
        assert_eq!(Policy::parse("dynamic").unwrap().name(), "dynamic,1");
        assert_eq!(Policy::parse("ich").unwrap().name(), "ich,0.33");
        assert_eq!(Policy::parse("factoring").unwrap().name(), "factoring,2");
    }

    #[test]
    fn table2_grid_matches_paper() {
        assert_eq!(table2_grid("dynamic").len(), 3);
        assert_eq!(table2_grid("guided").len(), 3);
        assert_eq!(table2_grid("binlpt").len(), 3);
        assert_eq!(table2_grid("stealing").len(), 4);
        assert_eq!(table2_grid("ich").len(), 3);
        assert_eq!(table2_grid("taskloop").len(), 1);
        assert!(table2_grid("unknown").is_empty());
    }

    #[test]
    fn parallel_for_each_sums() {
        let acc = AtomicU64::new(0);
        parallel_for_each(100, &Policy::Ich(IchParams::default()), &ForOpts::threads(3), &|i| {
            acc.fetch_add(i as u64, SeqCst);
        });
        assert_eq!(acc.load(SeqCst), 99 * 100 / 2);
    }

    #[test]
    fn parallel_for_async_sums() {
        let acc = Arc::new(AtomicU64::new(0));
        let a2 = Arc::clone(&acc);
        let opts = ForOpts { threads: 3, pin: false, ..Default::default() };
        let join = parallel_for_async(
            100,
            &Policy::Ich(IchParams::default()),
            &opts,
            Arc::new(move |r: std::ops::Range<usize>| {
                for i in r {
                    a2.fetch_add(i as u64, SeqCst);
                }
            }),
        );
        let m = join.join();
        assert_eq!(acc.load(SeqCst), 99 * 100 / 2);
        assert_eq!(m.total_iters, 100);
    }

    #[test]
    #[should_panic(expected = "weights length must equal n")]
    fn hss_wrong_weights_length_panics() {
        let w = [1.0f64; 5];
        let opts = ForOpts { threads: 2, pin: false, weights: Some(&w[..]), ..Default::default() };
        parallel_for(100, &Policy::Hss, &opts, &|_r| {});
    }

    #[test]
    #[should_panic(expected = "weights length must equal n")]
    fn binlpt_wrong_weights_length_panics() {
        let w = [1.0f64; 5];
        let opts = ForOpts { threads: 2, pin: false, weights: Some(&w[..]), ..Default::default() };
        parallel_for(100, &Policy::Binlpt { max_chunks: 8 }, &opts, &|_r| {});
    }

    #[test]
    fn representatives_cover_every_family_once() {
        let fams: Vec<&str> = Policy::representatives().iter().map(|p| p.family()).collect();
        let mut uniq = fams.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(fams.len(), 11);
        assert_eq!(uniq.len(), 11, "duplicate family in representatives: {fams:?}");
        assert!(fams.contains(&"auto"));
    }

    #[test]
    fn needs_weights_flags() {
        assert!(Policy::Binlpt { max_chunks: 8 }.needs_weights());
        assert!(Policy::Hss.needs_weights());
        assert!(!Policy::Ich(IchParams::default()).needs_weights());
    }

    #[test]
    fn dispatch_class_flows_into_run_metrics() {
        // Pool mode: the run queues as a real epoch, so the metrics
        // must carry the class and a measured queue wait.
        let opts = ForOpts { threads: 2, pin: false, ..Default::default() }
            .with_class(LatencyClass::Interactive)
            .with_deadline(9);
        let m = parallel_for(500, &Policy::Dynamic { chunk: 16 }, &opts, &|r| {
            std::hint::black_box(r.len());
        });
        assert_eq!(m.total_iters, 500);
        assert_eq!(m.class, LatencyClass::Interactive);
        assert!(m.queue_wait_s > 0.0, "pool-dispatched run must report its queue wait");
        assert!(m.dispatch_skips <= crate::sched::dispatch::PROMOTE_K);

        // Spawn mode never touches the dispatch queue: class is still
        // reported, wait stays zero.
        let opts = ForOpts { threads: 2, pin: false, mode: ExecMode::Spawn, ..Default::default() }
            .with_class(LatencyClass::Background);
        let m = parallel_for(100, &Policy::Static, &opts, &|_r| {});
        assert_eq!(m.class, LatencyClass::Background);
        assert_eq!(m.queue_wait_s, 0.0);
        assert!(!m.promoted);
    }
}
