//! L3 coordinator — the paper's contribution: a libgomp-like
//! loop-scheduling runtime with pluggable self-scheduling policies.
//!
//! Entry point: [`parallel_for`] — schedule `n` loop iterations over
//! `p` worker threads under a [`Policy`]. Bodies receive iteration
//! *ranges* so per-chunk dispatch overhead is amortized exactly the way
//! an OpenMP runtime amortizes it.
//!
//! Policies (paper Table 2 plus related-work extensions):
//! `static`, `dynamic,c`, `guided,c`, `taskloop`, `factoring`,
//! `binlpt,k` (workload-aware), `stealing,c` (fixed-chunk THE
//! work-stealing), **`ich,ε` (the paper's method)**, `awf`, `hss`.
//!
//! # Execution layer
//!
//! Engines do not spawn threads themselves: each one hands its worker
//! function to an [`Executor`] (`exec.run(p, f)` runs `f(tid)` exactly
//! once per `tid in 0..p` and joins). Two executors exist:
//!
//! - [`runtime::Runtime`] — the default: a **persistent, core-pinned
//!   worker pool**, spawned once per process and reused across
//!   `parallel_for` calls via an epoch-based fork-join barrier
//!   (spin→yield→park). One epoch = publish the type-erased loop body
//!   to `p − 1` parked workers, run tid 0 on the caller, then join on
//!   a pending-counter. Nested or concurrent `parallel_for` calls,
//!   and calls asking for more threads than the pool holds, fall back
//!   to scoped spawning — no deadlock, only degraded amortization.
//!   See `sched::runtime` for the full protocol and memory-ordering
//!   argument.
//! - [`SpawnExec`] — per-call scoped spawn + join (the seed behavior),
//!   selectable with [`ExecMode::Spawn`] for measurement baselines.
//!
//! [`ForOpts::mode`] picks between them; the fork-join overhead gap is
//! measured by `benches/bench_overhead.rs` (`BENCH_forkjoin.json`).

pub mod binlpt;
pub mod central;
pub mod deque;
pub mod metrics;
pub mod policy;
pub mod pool;
pub mod related;
pub mod runtime;
pub mod ws;

pub use metrics::{MetricsSink, RunMetrics};
pub use runtime::{Executor, Runtime, SpawnExec};
pub use ws::{IchParams, StealMerge};

use std::ops::Range;

/// A self-scheduling policy with its tuning parameters (paper Table 2).
#[derive(Clone, Debug)]
pub enum Policy {
    /// Even block partition, no runtime scheduling.
    Static,
    /// OpenMP `schedule(dynamic, chunk)`.
    Dynamic { chunk: usize },
    /// OpenMP `schedule(guided, chunk)` (chunk = minimum).
    Guided { chunk: usize },
    /// OpenMP `taskloop num_tasks(t)`; `0` means `num_threads`.
    Taskloop { num_tasks: usize },
    /// Factoring Self-Scheduling with batch factor `alpha` (≈2).
    Factoring { alpha: f64 },
    /// BinLPT with at most `max_chunks` chunks (needs `weights`).
    Binlpt { max_chunks: usize },
    /// Fixed-chunk THE work-stealing (the paper's base algorithm).
    Stealing { chunk: usize },
    /// iCh — the paper's adaptive-chunk work-stealing (§3).
    Ich(IchParams),
    /// Adaptive Weighted Factoring (related work, §4).
    Awf,
    /// History-aware static partition (HSS-lite, related work, §4).
    Hss,
}

impl Policy {
    /// Canonical short name used by the CLI and result files.
    pub fn name(&self) -> String {
        match self {
            Policy::Static => "static".into(),
            Policy::Dynamic { chunk } => format!("dynamic,{chunk}"),
            Policy::Guided { chunk } => format!("guided,{chunk}"),
            Policy::Taskloop { num_tasks } => format!("taskloop,{num_tasks}"),
            Policy::Factoring { alpha } => format!("factoring,{alpha}"),
            Policy::Binlpt { max_chunks } => format!("binlpt,{max_chunks}"),
            Policy::Stealing { chunk } => format!("stealing,{chunk}"),
            Policy::Ich(p) => format!("ich,{}", p.eps),
            Policy::Awf => "awf".into(),
            Policy::Hss => "hss".into(),
        }
    }

    /// Family name without parameters ("dynamic", "ich", ...).
    pub fn family(&self) -> &'static str {
        match self {
            Policy::Static => "static",
            Policy::Dynamic { .. } => "dynamic",
            Policy::Guided { .. } => "guided",
            Policy::Taskloop { .. } => "taskloop",
            Policy::Factoring { .. } => "factoring",
            Policy::Binlpt { .. } => "binlpt",
            Policy::Stealing { .. } => "stealing",
            Policy::Ich(_) => "ich",
            Policy::Awf => "awf",
            Policy::Hss => "hss",
        }
    }

    /// Parse "family,param" strings, e.g. "ich,0.33" or "dynamic,2".
    pub fn parse(s: &str) -> Option<Policy> {
        let (fam, arg) = match s.split_once(',') {
            Some((f, a)) => (f, Some(a)),
            None => (s, None),
        };
        fn num<T: std::str::FromStr>(arg: Option<&str>, default: T) -> Option<T> {
            match arg {
                None => Some(default),
                Some(a) => a.parse().ok(),
            }
        }
        Some(match fam {
            "static" => Policy::Static,
            "dynamic" => Policy::Dynamic { chunk: num(arg, 1)? },
            "guided" => Policy::Guided { chunk: num(arg, 1)? },
            "taskloop" => Policy::Taskloop { num_tasks: num(arg, 0)? },
            "factoring" => Policy::Factoring { alpha: num(arg, 2.0)? },
            "binlpt" => Policy::Binlpt { max_chunks: num(arg, 384)? },
            "stealing" => Policy::Stealing { chunk: num(arg, 1)? },
            "ich" => Policy::Ich(IchParams::with_eps(num(arg, 0.33)?)),
            "awf" => Policy::Awf,
            "hss" => Policy::Hss,
            _ => return None,
        })
    }

    /// Does this policy require per-iteration workload estimates?
    pub fn needs_weights(&self) -> bool {
        matches!(self, Policy::Binlpt { .. } | Policy::Hss)
    }

    /// One representative configuration per family — the canonical
    /// all-families list shared by the coverage tests, the pool stress
    /// suite, and the fork-join benchmark, so the three cannot drift.
    pub fn representatives() -> Vec<Policy> {
        vec![
            Policy::Static,
            Policy::Dynamic { chunk: 64 },
            Policy::Guided { chunk: 1 },
            Policy::Taskloop { num_tasks: 0 },
            Policy::Factoring { alpha: 2.0 },
            Policy::Binlpt { max_chunks: 64 },
            Policy::Stealing { chunk: 64 },
            Policy::Ich(IchParams::default()),
            Policy::Awf,
            Policy::Hss,
        ]
    }
}

/// How `parallel_for` obtains its worker threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// The shared persistent worker pool ([`Runtime::global`]).
    /// Falls back to scoped spawning when the pool is busy (nested or
    /// concurrent call) or smaller than `threads − 1`.
    #[default]
    Pool,
    /// Spawn and join fresh OS threads for this call (the seed
    /// runtime's strategy; also what the pool falls back to).
    Spawn,
}

/// Options for a `parallel_for` run.
#[derive(Clone, Debug)]
pub struct ForOpts<'a> {
    /// Worker thread count p.
    pub threads: usize,
    /// Pin threads to cores when the host has enough of them
    /// (OMP_PROC_BIND=true analog). Pool workers pin once at spawn,
    /// so this flag only governs [`ExecMode::Spawn`] runs (the pool's
    /// internal fallbacks never re-pin the calling thread).
    pub pin: bool,
    /// RNG seed for steal-victim selection (reproducibility).
    pub seed: u64,
    /// Per-iteration workload estimates — consumed only by
    /// workload-aware policies (BinLPT, HSS).
    pub weights: Option<&'a [f64]>,
    /// Worker-thread provider (persistent pool by default).
    pub mode: ExecMode,
}

impl Default for ForOpts<'_> {
    fn default() -> Self {
        ForOpts { threads: 1, pin: true, seed: 0x1C4, weights: None, mode: ExecMode::Pool }
    }
}

impl<'a> ForOpts<'a> {
    pub fn threads(p: usize) -> Self {
        ForOpts { threads: p, ..Default::default() }
    }

    pub fn with_weights(mut self, w: &'a [f64]) -> Self {
        self.weights = Some(w);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_mode(mut self, mode: ExecMode) -> Self {
        self.mode = mode;
        self
    }
}

/// Schedule `n` iterations over the configured threads; `body`
/// receives disjoint iteration ranges covering `0..n` exactly once.
/// Returns timing + scheduling metrics.
pub fn parallel_for(n: usize, policy: &Policy, opts: &ForOpts, body: &(dyn Fn(Range<usize>) + Sync)) -> RunMetrics {
    let p = opts.threads.max(1);
    let sink = MetricsSink::new(p);
    let spawn = SpawnExec::new(opts.pin);
    let pool;
    let exec: &dyn Executor = match opts.mode {
        // p == 1 runs inline either way; don't spawn the global pool
        // for callers that never fan out.
        ExecMode::Spawn => &spawn,
        ExecMode::Pool if p == 1 => &spawn,
        ExecMode::Pool => {
            pool = Runtime::global().executor();
            &pool
        }
    };
    let start = std::time::Instant::now();
    match policy {
        Policy::Static => central::run_static(n, p, exec, body, &sink),
        Policy::Dynamic { chunk } => central::run_dynamic(n, p, exec, *chunk, body, &sink),
        Policy::Guided { chunk } => central::run_guided(n, p, exec, *chunk, body, &sink),
        Policy::Taskloop { num_tasks } => central::run_taskloop(n, p, exec, *num_tasks, body, &sink),
        Policy::Factoring { alpha } => central::run_factoring(n, p, exec, *alpha, body, &sink),
        Policy::Binlpt { max_chunks } => {
            let uniform;
            let w = match opts.weights {
                Some(w) => {
                    assert_eq!(w.len(), n, "weights length must equal n");
                    w
                }
                None => {
                    // Workload-unaware fallback: uniform estimates.
                    uniform = vec![1.0; n];
                    &uniform
                }
            };
            binlpt::run_binlpt(w, p, exec, *max_chunks, body, &sink)
        }
        Policy::Stealing { chunk } => ws::run_stealing(n, p, exec, *chunk, opts.seed, body, &sink),
        Policy::Ich(prm) => ws::run_ich(n, p, exec, *prm, opts.seed, body, &sink),
        Policy::Awf => related::run_awf(n, p, exec, body, &sink),
        Policy::Hss => related::run_hss(n, p, exec, opts.weights, body, &sink),
    }
    sink.collect(start.elapsed())
}

/// Convenience: per-iteration body.
pub fn parallel_for_each(n: usize, policy: &Policy, opts: &ForOpts, f: &(dyn Fn(usize) + Sync)) -> RunMetrics {
    parallel_for(n, policy, opts, &|r: Range<usize>| {
        for i in r {
            f(i)
        }
    })
}

/// The paper's Table 2 parameter grid for a policy family, used by the
/// harness's best-over-params reporting (§6.1).
pub fn table2_grid(family: &str) -> Vec<Policy> {
    match family {
        "static" => vec![Policy::Static],
        "dynamic" => [1, 2, 3].iter().map(|&c| Policy::Dynamic { chunk: c }).collect(),
        "guided" => [1, 2, 3].iter().map(|&c| Policy::Guided { chunk: c }).collect(),
        "taskloop" => vec![Policy::Taskloop { num_tasks: 0 }],
        "factoring" => vec![Policy::Factoring { alpha: 2.0 }],
        "binlpt" => [128, 384, 576].iter().map(|&k| Policy::Binlpt { max_chunks: k }).collect(),
        "stealing" => [1, 2, 3, 64].iter().map(|&c| Policy::Stealing { chunk: c }).collect(),
        "ich" => [0.25, 0.33, 0.50].iter().map(|&e| Policy::Ich(IchParams::with_eps(e))).collect(),
        "awf" => vec![Policy::Awf],
        "hss" => vec![Policy::Hss],
        _ => vec![],
    }
}

/// The scheduler families the paper's figures compare.
pub const PAPER_FAMILIES: &[&str] = &["guided", "dynamic", "taskloop", "binlpt", "stealing", "ich"];

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering::SeqCst};

    #[test]
    fn every_policy_covers_exactly_once() {
        let n = 500;
        // Representatives (chunk 64: few, large dispatches) plus
        // deliberately tiny chunks — hundreds of dispatches per run —
        // so the exactly-once invariant is exercised under heavy
        // steal/claim contention on both executors.
        let mut policies = Policy::representatives();
        policies.extend([
            Policy::Dynamic { chunk: 2 },
            Policy::Stealing { chunk: 2 },
            Policy::Binlpt { max_chunks: 16 },
            Policy::Guided { chunk: 2 },
        ]);
        for mode in [ExecMode::Pool, ExecMode::Spawn] {
            for policy in &policies {
                let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                let w: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
                let opts = ForOpts { threads: 4, pin: false, seed: 1, weights: Some(&w), mode };
                let m = parallel_for(n, policy, &opts, &|r| {
                    for i in r {
                        hits[i].fetch_add(1, SeqCst);
                    }
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(SeqCst), 1, "policy {} mode {mode:?} iter {i}", policy.name());
                }
                assert_eq!(m.total_iters, n as u64, "policy {}", policy.name());
            }
        }
    }

    #[test]
    fn parse_round_trips() {
        for s in ["static", "dynamic,2", "guided,3", "taskloop,0", "binlpt,384", "stealing,64", "ich,0.25", "awf", "hss"] {
            let p = Policy::parse(s).unwrap();
            assert_eq!(p.name(), s, "parse/name mismatch for {s}");
        }
        assert!(Policy::parse("nonsense").is_none());
    }

    #[test]
    fn parse_defaults() {
        assert_eq!(Policy::parse("dynamic").unwrap().name(), "dynamic,1");
        assert_eq!(Policy::parse("ich").unwrap().name(), "ich,0.33");
    }

    #[test]
    fn table2_grid_matches_paper() {
        assert_eq!(table2_grid("dynamic").len(), 3);
        assert_eq!(table2_grid("guided").len(), 3);
        assert_eq!(table2_grid("binlpt").len(), 3);
        assert_eq!(table2_grid("stealing").len(), 4);
        assert_eq!(table2_grid("ich").len(), 3);
        assert_eq!(table2_grid("taskloop").len(), 1);
        assert!(table2_grid("unknown").is_empty());
    }

    #[test]
    fn parallel_for_each_sums() {
        let acc = AtomicU64::new(0);
        parallel_for_each(100, &Policy::Ich(IchParams::default()), &ForOpts::threads(3), &|i| {
            acc.fetch_add(i as u64, SeqCst);
        });
        assert_eq!(acc.load(SeqCst), 99 * 100 / 2);
    }

    #[test]
    fn representatives_cover_every_family_once() {
        let fams: Vec<&str> = Policy::representatives().iter().map(|p| p.family()).collect();
        let mut uniq = fams.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(fams.len(), 10);
        assert_eq!(uniq.len(), 10, "duplicate family in representatives: {fams:?}");
    }

    #[test]
    fn needs_weights_flags() {
        assert!(Policy::Binlpt { max_chunks: 8 }.needs_weights());
        assert!(Policy::Hss.needs_weights());
        assert!(!Policy::Ich(IchParams::default()).needs_weights());
    }
}
