//! THE-protocol iteration-range deque (paper §3.3, Listing 1).
//!
//! Each worker owns a contiguous iteration range `[begin, end)`. The
//! owner dispatches chunks from the `begin` side without taking a lock
//! on the fast path; thieves cut `halfsize` iterations off the `end`
//! side under the queue's mutex, rolling back if the owner raced past
//! (Listing 1 lines 12–16). This mirrors Cilk's THE handshake: both
//! sides publish with SeqCst stores and re-check the opposite index.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering::SeqCst};
use std::sync::Mutex;

/// A work queue holding a single contiguous range of loop iterations.
pub struct RangeDeque {
    begin: AtomicUsize,
    end: AtomicUsize,
    lock: Mutex<()>,
}

impl RangeDeque {
    pub fn new(range: Range<usize>) -> RangeDeque {
        RangeDeque {
            begin: AtomicUsize::new(range.start),
            end: AtomicUsize::new(range.end),
            lock: Mutex::new(()),
        }
    }

    /// Remaining iterations (a racy estimate, used for chunk sizing and
    /// steal-victim probing; exactness is not required).
    #[inline]
    pub fn remaining(&self) -> usize {
        let e = self.end.load(SeqCst);
        let b = self.begin.load(SeqCst);
        e.saturating_sub(b)
    }

    /// Owner-side dispatch of up to `chunk` iterations. Lock-free on
    /// the common path; falls back to the mutex only when a concurrent
    /// thief cut `end` below our optimistic claim.
    pub fn take(&self, chunk: usize) -> Option<Range<usize>> {
        debug_assert!(chunk > 0);
        let b = self.begin.load(SeqCst);
        let nb = b.saturating_add(chunk);
        // Optimistically claim [b, nb): only the owner writes `begin`,
        // so a plain store is safe with respect to other owners.
        self.begin.store(nb, SeqCst);
        let e = self.end.load(SeqCst);
        if nb <= e {
            return Some(b..nb); // fast path: no conflict
        }
        // Conflict: a thief moved `end` (or the queue is empty).
        // Resolve under the lock, exactly like the THE slow path.
        let _g = self.lock.lock().unwrap();
        let e = self.end.load(SeqCst);
        if b >= e {
            // Nothing left; undo the optimistic claim.
            self.begin.store(b, SeqCst);
            return None;
        }
        let take = chunk.min(e - b);
        self.begin.store(b + take, SeqCst);
        Some(b..b + take)
    }

    /// Thief-side steal of half the victim's remaining iterations
    /// (Listing 1). Returns the stolen range, or None if the victim is
    /// empty or the owner raced us (rollback).
    pub fn steal_half(&self) -> Option<Range<usize>> {
        let _g = self.lock.lock().unwrap();
        let b = self.begin.load(SeqCst);
        let e = self.end.load(SeqCst);
        if e <= b {
            return None; // line 2: nothing to steal
        }
        let half = (e - b).div_ceil(2); // line 4: half, at least 1
        let ne = e - half;
        self.end.store(ne, SeqCst); // line 11
        // Re-check against the owner's (possibly concurrent) progress.
        let b2 = self.begin.load(SeqCst);
        if ne < b2 {
            // lines 12–16: abort — roll the end pointer back.
            self.end.store(e, SeqCst);
            return None;
        }
        Some(ne..e)
    }

    /// Used by tests / metrics: true when all iterations dispatched.
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Re-home a stolen range into this (drained) queue so it becomes
    /// visible for further stealing (Listing 1 lines 23–24). Taken
    /// under the queue's own lock so concurrent thieves cannot observe
    /// a torn begin/end pair; the owner is the caller, so no owner race
    /// exists.
    pub fn reset(&self, r: Range<usize>) {
        let _g = self.lock.lock().unwrap();
        debug_assert!(self.end.load(SeqCst) <= self.begin.load(SeqCst), "reset requires a drained queue");
        // Order matters for lock-free readers of `remaining`: shrink
        // first (end ≤ begin keeps it observably empty), then publish.
        self.end.store(r.start, SeqCst);
        self.begin.store(r.start, SeqCst);
        self.end.store(r.end, SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn owner_drains_sequentially() {
        let q = RangeDeque::new(0..10);
        assert_eq!(q.take(4), Some(0..4));
        assert_eq!(q.take(4), Some(4..8));
        assert_eq!(q.take(4), Some(8..10)); // clamped
        assert_eq!(q.take(4), None);
        assert!(q.is_empty());
    }

    #[test]
    fn steal_takes_half_rounding_up() {
        let q = RangeDeque::new(0..10);
        assert_eq!(q.steal_half(), Some(5..10)); // 10 left -> steal 5
        assert_eq!(q.steal_half(), Some(2..5)); // 5 left -> steal ceil(5/2)=3
        assert_eq!(q.steal_half(), Some(1..2)); // 2 left -> steal 1
        assert_eq!(q.steal_half(), Some(0..1)); // 1 left -> steal 1
        assert_eq!(q.steal_half(), None);
    }

    #[test]
    fn interleaved_take_and_steal_disjoint() {
        let q = RangeDeque::new(0..100);
        let a = q.take(10).unwrap();
        let s = q.steal_half().unwrap();
        let b = q.take(10).unwrap();
        assert_eq!(a, 0..10);
        assert_eq!(s, 55..100);
        assert_eq!(b, 10..20);
    }

    #[test]
    fn concurrent_no_duplication_no_loss() {
        // Hammer one queue with an owner and several thieves; every
        // iteration must be claimed exactly once.
        const N: usize = 100_000;
        let q = Arc::new(RangeDeque::new(0..N));
        let claimed: Arc<Vec<AtomicU64>> = Arc::new((0..N).map(|_| AtomicU64::new(0)).collect());

        std::thread::scope(|s| {
            // owner
            {
                let q = q.clone();
                let claimed = claimed.clone();
                s.spawn(move || {
                    let mut c = 1usize;
                    while let Some(r) = q.take(c) {
                        for i in r {
                            claimed[i].fetch_add(1, SeqCst);
                        }
                        c = (c % 7) + 1; // vary chunk size
                    }
                });
            }
            // thieves
            for _ in 0..3 {
                let q = q.clone();
                let claimed = claimed.clone();
                s.spawn(move || {
                    let mut fails = 0;
                    while fails < 1000 {
                        match q.steal_half() {
                            Some(r) => {
                                fails = 0;
                                for i in r {
                                    claimed[i].fetch_add(1, SeqCst);
                                }
                            }
                            None => {
                                fails += 1;
                                std::hint::spin_loop();
                            }
                        }
                    }
                });
            }
        });

        for (i, c) in claimed.iter().enumerate() {
            assert_eq!(c.load(SeqCst), 1, "iteration {i} claimed {} times", c.load(SeqCst));
        }
    }

    #[test]
    fn steal_after_drain_fails() {
        let q = RangeDeque::new(0..4);
        q.take(4).unwrap();
        assert_eq!(q.steal_half(), None);
    }

    #[test]
    fn empty_queue() {
        let q = RangeDeque::new(5..5);
        assert!(q.is_empty());
        assert_eq!(q.take(1), None);
        assert_eq!(q.steal_half(), None);
    }
}
