//! THE-protocol iteration-range deque (paper §3.3, Listing 1).
//!
//! Each worker owns a contiguous iteration range `[begin, end)`. The
//! owner dispatches chunks from the `begin` side without taking a lock
//! on the fast path; thieves cut `halfsize` iterations off the `end`
//! side under the queue's mutex, rolling back if the owner raced past
//! (Listing 1 lines 12–16). This mirrors Cilk's THE handshake: both
//! sides publish with SeqCst stores and re-check the opposite index.
//!
//! # Overshoot invariant (PR 3 bugfix)
//!
//! The owner's optimistic `begin` store is **clamped to the
//! last-observed `end`**, so `begin` never publishes past `end`.
//! The seed stored `begin = b + chunk` unclamped; whenever
//! `chunk > remaining` (every tail take), `begin` transiently held a
//! value beyond `end` until the locked slow path repaired it. In that
//! window a concurrent `remaining()` probe read 0 and a concurrent
//! `steal_half` — even one that won the race to the lock — returned
//! `None`, although the tail iterations were not yet claimed by
//! anyone: informed-steal probes skipped a non-empty victim and
//! random steals failed for no reason. With the clamp, the optimistic
//! store *is* the claim: `remaining() == 0` now implies every
//! iteration is genuinely claimed, and the common tail take no longer
//! touches the mutex at all (the slow path is reached only when a
//! thief concurrently cut `end` below the claim — the true THE
//! conflict).

use std::ops::Range;
use std::sync::atomic::Ordering::SeqCst;

// Checker-aware aliases: std types in production, `crate::check` shims
// in test/check builds so `check::models::deque` explores this exact
// code under exhaustive interleaving search (see `util::sync::shim`).
use crate::util::sync::shim::{AtomicUsize, Mutex};

/// A work queue holding a single contiguous range of loop iterations.
pub struct RangeDeque {
    begin: AtomicUsize,
    end: AtomicUsize,
    lock: Mutex<()>,
}

impl RangeDeque {
    pub fn new(range: Range<usize>) -> RangeDeque {
        RangeDeque {
            begin: AtomicUsize::new(range.start),
            end: AtomicUsize::new(range.end),
            lock: Mutex::new(()),
        }
    }

    /// Remaining iterations (a racy estimate, used for chunk sizing and
    /// steal-victim probing; exactness is not required).
    #[inline]
    pub fn remaining(&self) -> usize {
        let e = self.end.load(SeqCst); // order: [deque.probe] SeqCst paired reads; lock-free progress probe
        let b = self.begin.load(SeqCst); // order: [deque.probe] SeqCst paired reads; lock-free progress probe
        e.saturating_sub(b)
    }

    /// Owner-side dispatch of up to `chunk` iterations. Lock-free on
    /// the common path — *including* the tail take where
    /// `chunk > remaining`: the optimistic claim is clamped to the
    /// last-observed `end` (module docs, "Overshoot invariant"), so
    /// the mutex is needed only when a concurrent thief cut `end`
    /// below the claim between the two loads.
    pub fn take(&self, chunk: usize) -> Option<Range<usize>> {
        self.take_impl(chunk, || {})
    }

    /// `take` with a probe hook between the optimistic claim and the
    /// conflict check: the regression tests use it to freeze the THE
    /// window and look at the deque from a thief's point of view.
    #[inline]
    fn take_impl(&self, chunk: usize, mid_claim: impl FnOnce()) -> Option<Range<usize>> {
        debug_assert!(chunk > 0);
        let b = self.begin.load(SeqCst); // order: [deque.claim-publish] SeqCst — owner fast path and thief cut form one total order
        let e0 = self.end.load(SeqCst); // order: [deque.cut-clamp] SeqCst — bounds the THE clamp below
        if b >= e0 {
            return None; // already drained; no store, no lock
        }
        // Optimistically claim [b, nb): only the owner writes `begin`,
        // so a plain store is safe with respect to other owners. The
        // clamp to `e0` keeps `begin ≤ end` — publishing past `end`
        // made concurrent thieves observe an empty non-empty deque
        // (module docs).
        let nb = b.saturating_add(chunk).min(e0);
        self.begin.store(nb, SeqCst); // order: [deque.claim-publish] SeqCst optimistic claim (THE clamp: nb never passes max end)
        mid_claim();
        let e = self.end.load(SeqCst); // order: [deque.cut-clamp] SeqCst conflict re-check against a concurrent steal cut
        if nb <= e {
            return Some(b..nb); // fast path: no conflict
        }
        // Conflict: a thief cut `end` below our claim between the two
        // loads. Resolve under the lock, exactly like the THE slow
        // path; whatever is left of [b, e) is ours (`e − b < chunk`
        // here, so the owner takes the whole remainder).
        let _g = self.lock.lock().unwrap();
        let e = self.end.load(SeqCst); // order: [deque.cut-clamp] SeqCst re-read under the lock (thief quiesced)
        if b >= e {
            // Nothing left; undo the optimistic claim.
            self.begin.store(b, SeqCst); // order: [deque.claim-publish] SeqCst rollback of the optimistic claim
            return None;
        }
        let take = chunk.min(e - b);
        self.begin.store(b + take, SeqCst); // order: [deque.cut-clamp] SeqCst clamped claim under the lock
        Some(b..b + take)
    }

    /// Thief-side steal of half the victim's remaining iterations
    /// (Listing 1). Returns the stolen range, or None if the victim is
    /// empty or the owner raced us (rollback).
    pub fn steal_half(&self) -> Option<Range<usize>> {
        self.steal_half_with_len().map(|(r, _)| r)
    }

    /// [`RangeDeque::steal_half`], also reporting the victim's
    /// pre-steal queue length: Listing 1 lines 20–22 size the thief's
    /// chunk clamp against the queue the steal cut from (see
    /// `policy::clamp_chunk_to_stolen`).
    pub fn steal_half_with_len(&self) -> Option<(Range<usize>, usize)> {
        let _g = self.lock.lock().unwrap();
        let b = self.begin.load(SeqCst); // order: [deque.claim-publish] SeqCst read under the lock; races only the owner fast path
        let e = self.end.load(SeqCst); // order: [deque.claim-publish] SeqCst read under the lock; races only the owner fast path
        if e <= b {
            return None; // line 2: nothing to steal
        }
        let half = (e - b).div_ceil(2); // line 4: half, at least 1
        let ne = e - half;
        self.end.store(ne, SeqCst); // line 11 // order: [deque.cut-clamp] SeqCst cut; owner's in-flight take re-checks end after this
        // Re-check against the owner's (possibly concurrent) progress.
        let b2 = self.begin.load(SeqCst); // order: [deque.claim-publish] SeqCst re-check against the owner's optimistic claim
        if ne < b2 {
            // lines 12–16: abort — roll the end pointer back.
            self.end.store(e, SeqCst); // order: [deque.cut-clamp] SeqCst rollback of the cut
            return None;
        }
        Some((ne..e, e - b))
    }

    /// Used by tests / metrics: true when all iterations dispatched.
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Re-home a stolen range into this (drained) queue so it becomes
    /// visible for further stealing (Listing 1 lines 23–24). Taken
    /// under the queue's own lock so concurrent thieves cannot observe
    /// a torn begin/end pair; the owner is the caller, so no owner race
    /// exists.
    pub fn reset(&self, r: Range<usize>) {
        let _g = self.lock.lock().unwrap();
        debug_assert!(self.end.load(SeqCst) <= self.begin.load(SeqCst), "reset requires a drained queue"); // order: [deque.cut-clamp] SeqCst drained-queue check under the lock
        // Order matters for lock-free readers of `remaining`: shrink
        // first (end ≤ begin keeps it observably empty), then publish.
        self.end.store(r.start, SeqCst); // order: [deque.cut-clamp] SeqCst shrink-then-publish (comment above)
        self.begin.store(r.start, SeqCst); // order: [deque.cut-clamp] SeqCst shrink-then-publish (comment above)
        self.end.store(r.end, SeqCst); // order: [deque.cut-clamp] SeqCst shrink-then-publish (comment above)
    }

    /// Raw `(begin, end)` snapshot for the invariant tests and the
    /// model checker's whole-state `begin ≤ end` invariant
    /// (`check::models::deque`).
    #[cfg(any(test, feature = "check"))]
    pub(crate) fn raw(&self) -> (usize, usize) {
        (self.begin.load(SeqCst), self.end.load(SeqCst)) // order: [deque.probe] SeqCst snapshot for the checker's invariants
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn owner_drains_sequentially() {
        let q = RangeDeque::new(0..10);
        assert_eq!(q.take(4), Some(0..4));
        assert_eq!(q.take(4), Some(4..8));
        assert_eq!(q.take(4), Some(8..10)); // clamped
        assert_eq!(q.take(4), None);
        assert!(q.is_empty());
    }

    #[test]
    fn steal_takes_half_rounding_up() {
        let q = RangeDeque::new(0..10);
        assert_eq!(q.steal_half(), Some(5..10)); // 10 left -> steal 5
        assert_eq!(q.steal_half(), Some(2..5)); // 5 left -> steal ceil(5/2)=3
        assert_eq!(q.steal_half(), Some(1..2)); // 2 left -> steal 1
        assert_eq!(q.steal_half(), Some(0..1)); // 1 left -> steal 1
        assert_eq!(q.steal_half(), None);
    }

    #[test]
    fn interleaved_take_and_steal_disjoint() {
        let q = RangeDeque::new(0..100);
        let a = q.take(10).unwrap();
        let s = q.steal_half().unwrap();
        let b = q.take(10).unwrap();
        assert_eq!(a, 0..10);
        assert_eq!(s, 55..100);
        assert_eq!(b, 10..20);
    }

    #[test]
    fn concurrent_no_duplication_no_loss() {
        // Hammer one queue with an owner and several thieves; every
        // iteration must be claimed exactly once.
        // Miri interprets every access: shrink the grind so the
        // nightly job finishes while still crossing the slow path.
        const N: usize = if cfg!(miri) { 400 } else { 100_000 };
        let q = Arc::new(RangeDeque::new(0..N));
        let claimed: Arc<Vec<AtomicU64>> = Arc::new((0..N).map(|_| AtomicU64::new(0)).collect());

        std::thread::scope(|s| {
            // owner
            {
                let q = q.clone();
                let claimed = claimed.clone();
                s.spawn(move || {
                    let mut c = 1usize;
                    while let Some(r) = q.take(c) {
                        for i in r {
                            claimed[i].fetch_add(1, SeqCst);
                        }
                        c = (c % 7) + 1; // vary chunk size
                    }
                });
            }
            // thieves
            for _ in 0..3 {
                let q = q.clone();
                let claimed = claimed.clone();
                s.spawn(move || {
                    let mut fails = 0;
                    while fails < 1000 {
                        match q.steal_half() {
                            Some(r) => {
                                fails = 0;
                                for i in r {
                                    claimed[i].fetch_add(1, SeqCst);
                                }
                            }
                            None => {
                                fails += 1;
                                std::hint::spin_loop();
                            }
                        }
                    }
                });
            }
        });

        for (i, c) in claimed.iter().enumerate() {
            assert_eq!(c.load(SeqCst), 1, "iteration {i} claimed {} times", c.load(SeqCst));
        }
    }

    #[test]
    fn overshooting_take_never_publishes_begin_past_end() {
        // Regression (PR 3): `take` used to store `begin = b + chunk`
        // even when that overshot `end`. Until the locked slow path
        // repaired it, a concurrent thief observed `remaining() == 0`
        // and `steal_half` rolled back spuriously — an "empty"
        // observation of a deque whose tail (4..10 here) was not yet
        // claimed by anyone. The probe hook freezes the THE window
        // mid-take and checks what a thief would see.
        let q = RangeDeque::new(0..10);
        assert_eq!(q.take(4), Some(0..4));
        let r = q.take_impl(100, || {
            let (b, e) = q.raw();
            assert!(b <= e, "optimistic claim overshot end: begin={b} > end={e}");
            // With the clamped claim the in-flight take already owns
            // the whole tail, so steal-side observations report a
            // *truthfully* empty deque rather than a corrupted one.
            assert_eq!(q.remaining(), 0);
            assert_eq!(q.steal_half(), None);
        });
        assert_eq!(r, Some(4..10), "the clamped claim is the returned chunk");
        assert!(q.is_empty());
    }

    #[test]
    fn drained_take_leaves_indices_untouched() {
        // The empty case exits before the optimistic store: no
        // transient scribble on `begin`, no lock traffic.
        let q = RangeDeque::new(0..4);
        assert_eq!(q.take(4), Some(0..4));
        assert_eq!(q.take(5), None);
        assert_eq!(q.raw(), (4, 4));
    }

    #[test]
    fn steal_half_reports_victim_len() {
        let q = RangeDeque::new(0..10);
        let (r, vlen) = q.steal_half_with_len().unwrap();
        assert_eq!(r, 5..10);
        assert_eq!(vlen, 10);
        let (r, vlen) = q.steal_half_with_len().unwrap();
        assert_eq!(r, 2..5);
        assert_eq!(vlen, 5);
    }

    #[test]
    fn oversized_tail_takes_race_thieves_exactly_once() {
        // Every owner take requests more than the live remainder —
        // the worst case for the old overshoot — while thieves hammer
        // `steal_half`. Exactly-once coverage must hold through the
        // clamped fast path and the conflict slow path, round after
        // round.
        use std::sync::atomic::AtomicBool;
        const K: usize = 8;
        const ROUNDS: usize = if cfg!(miri) { 20 } else { 2_000 }; // shrunk under Miri
        let q = Arc::new(RangeDeque::new(0..0));
        let marks: Arc<Vec<AtomicU64>> = Arc::new((0..K).map(|_| AtomicU64::new(0)).collect());
        let claimed = Arc::new(AtomicUsize::new(0)); // items claimed this round
        let stop = Arc::new(AtomicBool::new(false));

        std::thread::scope(|s| {
            for _ in 0..2 {
                let (q, marks, claimed, stop) = (q.clone(), marks.clone(), claimed.clone(), stop.clone());
                s.spawn(move || {
                    while !stop.load(SeqCst) {
                        if let Some(r) = q.steal_half() {
                            for i in r.clone() {
                                marks[i].fetch_add(1, SeqCst);
                            }
                            claimed.fetch_add(r.len(), SeqCst);
                        } else {
                            std::hint::spin_loop();
                        }
                    }
                });
            }
            // Owner: refill, then drain with always-oversized takes.
            for _ in 0..ROUNDS {
                q.reset(0..K);
                loop {
                    let rem = q.remaining();
                    if let Some(r) = q.take(rem.max(1) + 3) {
                        for i in r.clone() {
                            marks[i].fetch_add(1, SeqCst);
                        }
                        claimed.fetch_add(r.len(), SeqCst);
                    }
                    if claimed.load(SeqCst) == K {
                        break;
                    }
                    std::hint::spin_loop();
                }
                for (i, m) in marks.iter().enumerate() {
                    assert_eq!(m.swap(0, SeqCst), 1, "iteration {i} not claimed exactly once");
                }
                claimed.store(0, SeqCst);
            }
            stop.store(true, SeqCst);
        });
    }

    #[test]
    fn steal_after_drain_fails() {
        let q = RangeDeque::new(0..4);
        q.take(4).unwrap();
        assert_eq!(q.steal_half(), None);
    }

    #[test]
    fn empty_queue() {
        let q = RangeDeque::new(5..5);
        assert!(q.is_empty());
        assert_eq!(q.take(1), None);
        assert_eq!(q.steal_half(), None);
    }
}
