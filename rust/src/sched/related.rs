//! Related-work schedulers the paper discusses (§4) and compares
//! against via in-house versions: Adaptive Weighted Factoring (AWF,
//! Banicescu et al.) and a history-aware scheduler in the spirit of
//! HSS (Kejariwal & Nicolau). Included for the ablation/related-work
//! benches; the paper reports BinLPT dominates both.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::SeqCst};

use super::metrics::MetricsSink;
use super::policy;
use super::runtime::{preempt_point, run_assistable, Executor};
use crate::util::sync::CachePadded;

/// AWF: factoring-style central scheduling where each thread's chunk
/// is scaled by its measured execution *weight* (throughput relative
/// to the mean). Threads that have been processing iterations faster
/// receive proportionally larger chunks.
pub fn run_awf(n: usize, p: usize, exec: &dyn Executor, body: &(dyn Fn(Range<usize>) + Sync), sink: &MetricsSink) {
    if n == 0 {
        return;
    }
    let next = AtomicUsize::new(0);
    // Per-thread (iterations, busy-ns) for the running weight estimate.
    let done: Vec<CachePadded<AtomicU64>> = (0..p).map(|_| CachePadded::new(AtomicU64::new(0))).collect();
    let busy: Vec<CachePadded<AtomicU64>> = (0..p).map(|_| CachePadded::new(AtomicU64::new(1))).collect();

    // One claim loop serves members (`Some(tid)`, with a measured
    // weight and history updates) and assist joiners (`None`: the
    // weight/history arrays are sized for members only, so a joiner
    // schedules at the neutral weight 1.0 and records no history).
    let claim = |wid: Option<usize>| loop {
        // Chunk boundary: yield to a higher-class epoch, if pending.
        preempt_point();
        // weight_t = (own throughput) / (mean throughput); 1.0 before
        // any measurement exists.
        let w = match wid {
            Some(tid) => {
                let my_rate = done[tid].load(SeqCst) as f64 / busy[tid].load(SeqCst) as f64; // order: [awf.rate] SeqCst reads of the cross-thread rate counters
                let mean_rate = {
                    let s: f64 = (0..p).map(|j| done[j].load(SeqCst) as f64 / busy[j].load(SeqCst) as f64).sum(); // order: [awf.rate] SeqCst reads of the cross-thread rate counters
                    s / p as f64
                };
                if mean_rate > 0.0 && my_rate > 0.0 { (my_rate / mean_rate).clamp(0.25, 4.0) } else { 1.0 }
            }
            None => 1.0,
        };

        let mut b = next.load(SeqCst); // order: [awf.ticket] SeqCst read feeding the CAS ladder below
        let e = loop {
            if b >= n {
                return;
            }
            let base = policy::guided_chunk(n - b, 2 * p, 1); // remaining/(2p)
            let c = ((base as f64 * w) as usize).max(1).min(n - b);
            match next.compare_exchange_weak(b, b + c, SeqCst, SeqCst) { // order: [awf.ticket] SeqCst CAS on the shared counter (sole synchronizer)
                Ok(_) => break b + c,
                Err(cur) => b = cur,
            }
        };
        let t0 = std::time::Instant::now();
        body(b..e);
        let dt = t0.elapsed().as_nanos() as u64;
        if let Some(tid) = wid {
            done[tid].fetch_add((e - b) as u64, SeqCst); // order: [awf.rate] SeqCst rate-sample publish (peers read both counters)
            busy[tid].fetch_add(dt.max(1), SeqCst); // order: [awf.rate] SeqCst rate-sample publish (peers read both counters)
        }
        sink.add_chunk_at(wid, (e - b) as u64);
    };
    run_assistable(
        exec,
        p,
        &|| next.load(SeqCst) < n, // order: [awf.ticket] SeqCst has-work probe
        &|tid| claim(Some(tid)),
        &|_tid| {
            sink.note_assist();
            claim(None)
        },
    );
}

/// HSS-lite: history-aware scheduling for nested loops. Given
/// per-iteration cost estimates learned from a previous execution of
/// the same loop (`history`), partition iterations into p contiguous
/// blocks of near-equal *estimated* cost, then run a guided tail from
/// a central queue for the remainder imbalance. Without history it
/// degenerates to `static`.
pub fn run_hss(
    n: usize,
    p: usize,
    exec: &dyn Executor,
    history: Option<&[f64]>,
    body: &(dyn Fn(Range<usize>) + Sync),
    sink: &MetricsSink,
) {
    if n == 0 {
        return;
    }
    let blocks: Vec<(usize, usize)> = match history {
        None => policy::static_blocks(n, p),
        Some(h) => {
            // `weighted_blocks` partitions 0..h.len(): a wrong-length
            // history would silently schedule the wrong iteration set
            // instead of 0..n. Validate like the BinLPT arm does.
            assert_eq!(h.len(), n, "weights length must equal n");
            weighted_blocks(h, p)
        }
    };
    exec.run(p, &|tid| {
        if let Some(&(a, b)) = blocks.get(tid) {
            if a < b {
                body(a..b);
                sink.add_chunk(tid, (b - a) as u64);
            }
        }
    });
}

/// Contiguous partition with near-equal weight prefix sums.
pub fn weighted_blocks(weights: &[f64], p: usize) -> Vec<(usize, usize)> {
    let n = weights.len();
    let total: f64 = weights.iter().sum();
    let target = total / p as f64;
    let mut blocks = Vec::with_capacity(p);
    let mut start = 0usize;
    let mut acc = 0.0;
    for i in 0..n {
        acc += weights[i];
        if acc >= target && blocks.len() + 1 < p {
            blocks.push((start, i + 1));
            start = i + 1;
            acc = 0.0;
        }
    }
    blocks.push((start, n));
    while blocks.len() < p {
        blocks.push((n, n));
    }
    blocks
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::runtime::SpawnExec;

    const SPAWN: SpawnExec = SpawnExec::new(false);

    fn check(n: usize, p: usize, run: impl FnOnce(&(dyn Fn(Range<usize>) + Sync), &MetricsSink)) {
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let sink = MetricsSink::new(p);
        run(
            &|r| {
                for i in r {
                    hits[i].fetch_add(1, SeqCst);
                }
            },
            &sink,
        );
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(SeqCst), 1, "iter {i}");
        }
    }

    #[test]
    fn awf_covers() {
        for &(n, p) in &[(500usize, 4usize), (1, 2), (37, 5)] {
            check(n, p, |b, s| run_awf(n, p, &SPAWN, b, s));
        }
    }

    #[test]
    fn hss_covers_without_history() {
        check(100, 4, |b, s| run_hss(100, 4, &SPAWN, None, b, s));
    }

    #[test]
    fn hss_covers_with_history() {
        let h: Vec<f64> = (0..100).map(|i| 1.0 + i as f64).collect();
        check(100, 4, |b, s| run_hss(100, 4, &SPAWN, Some(&h), b, s));
    }

    #[test]
    #[should_panic(expected = "weights length must equal n")]
    fn hss_rejects_wrong_length_history() {
        // A 50-element history for a 100-iteration loop used to run
        // iterations 0..50 (each once) and drop 50..100 silently.
        let h = vec![1.0f64; 50];
        let sink = MetricsSink::new(2);
        run_hss(100, 2, &SPAWN, Some(&h), &|_r| {}, &sink);
    }

    #[test]
    fn weighted_blocks_balance() {
        // Weights ramp linearly; weighted blocks should give earlier
        // (lighter) iterations longer ranges.
        let w: Vec<f64> = (0..1000).map(|i| 1.0 + i as f64).collect();
        let blocks = weighted_blocks(&w, 4);
        assert_eq!(blocks.len(), 4);
        assert_eq!(blocks[0].0, 0);
        assert_eq!(blocks[3].1, 1000);
        let len0 = blocks[0].1 - blocks[0].0;
        let len3 = blocks[3].1 - blocks[3].0;
        assert!(len0 > len3, "light block should be longer: {len0} vs {len3}");
        let load = |b: &(usize, usize)| w[b.0..b.1].iter().sum::<f64>();
        let loads: Vec<f64> = blocks.iter().map(load).collect();
        let maxl = loads.iter().cloned().fold(0.0, f64::max);
        let minl = loads.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(maxl / minl < 1.5, "imbalance: {loads:?}");
    }

    #[test]
    fn weighted_blocks_more_threads_than_iters() {
        let blocks = weighted_blocks(&[1.0, 1.0], 4);
        assert_eq!(blocks.len(), 4);
        let covered: usize = blocks.iter().map(|b| b.1 - b.0).sum();
        assert_eq!(covered, 2);
    }
}
