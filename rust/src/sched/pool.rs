//! Thread-placement primitives and the per-call scoped spawner.
//!
//! The paper pins OpenMP threads to cores (`OMP_PROC_BIND=true`,
//! `OMP_PLACES=cores`). We do the same via `sched_setaffinity` (raw
//! FFI — the `libc` crate is unavailable offline) when the machine has
//! at least as many cores as requested threads; otherwise (e.g. a
//! 1-core container) pinning is skipped — the schedulers remain
//! correct, merely oversubscribed.
//!
//! [`scoped_run`] spawns and joins fresh OS threads for every call.
//! It is the oversubscription/nesting fallback of the persistent
//! worker pool in [`super::runtime`], which is what `parallel_for`
//! uses by default — see that module for the epoch fork-join protocol
//! that amortizes this per-call spawn cost away.

use std::cell::Cell;
use std::sync::OnceLock;

thread_local! {
    /// Core this thread was last *successfully* pinned to (`None` =
    /// never pinned, or the pin syscall failed — e.g. the target core
    /// sits outside a `taskset` affinity mask). The topology layer
    /// ([`super::topology::current_node`]) maps it to a NUMA node for
    /// steal-victim locality, so correctness of the map depends on
    /// recording only pins that actually took effect.
    static PINNED_CORE: Cell<Option<usize>> = Cell::new(None);
}

/// The core the calling thread is pinned to, if `pin_to_cpu` ever
/// succeeded on this thread.
pub fn pinned_core() -> Option<usize> {
    PINNED_CORE.with(|c| c.get())
}

// Miri cannot interpret foreign calls: every libc entry point below
// is compiled out under `cfg(miri)` and the portable fallbacks take
// over (available_parallelism, no-op pinning).
#[cfg(all(target_os = "linux", not(miri)))]
mod ffi {
    /// glibc/musl value of `_SC_NPROCESSORS_ONLN` on Linux.
    pub const SC_NPROCESSORS_ONLN: i32 = 84;

    /// C `long`: pointer-width on every Linux ABI (LP64 / ILP32), so
    /// a fixed `i64` would be ABI-wrong on 32-bit targets.
    pub type CLong = isize;

    extern "C" {
        pub fn sysconf(name: i32) -> CLong;
        /// `cpu_set_t` is a 1024-bit mask; we pass it as `[u64; 16]`.
        pub fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
        pub fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
    }
}

#[cfg(all(target_os = "linux", not(miri)))]
fn detect_cpus() -> usize {
    // SAFETY: sysconf is async-signal-safe and has no memory effects.
    let n = unsafe { ffi::sysconf(ffi::SC_NPROCESSORS_ONLN) };
    if n <= 0 { 1 } else { n as usize }
}

#[cfg(any(not(target_os = "linux"), miri))]
fn detect_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Number of online CPUs, detected once and cached (the seed runtime
/// re-ran the `sysconf` syscall on every call — including from
/// `pin_to_cpu` inside every worker spawn).
pub fn num_cpus() -> usize {
    static NCPUS: OnceLock<usize> = OnceLock::new();
    *NCPUS.get_or_init(detect_cpus)
}

/// Pin the calling thread to `cpu` (mod the core count; best-effort,
/// errors ignored; no-op off Linux).
#[cfg(all(target_os = "linux", not(miri)))]
pub fn pin_to_cpu(cpu: usize) {
    let cpu = cpu % num_cpus();
    let mut mask = [0u64; 16]; // 1024-bit cpu_set_t
    let (word, bit) = (cpu / 64, cpu % 64);
    if word >= mask.len() {
        return;
    }
    mask[word] = 1u64 << bit;
    // SAFETY: a properly sized, initialized affinity mask for self (pid 0).
    let r = unsafe { ffi::sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
    if r == 0 {
        PINNED_CORE.with(|c| c.set(Some(cpu)));
    }
}

/// Pin the calling thread to `cpu` (no-op off Linux).
#[cfg(any(not(target_os = "linux"), miri))]
pub fn pin_to_cpu(_cpu: usize) {}

/// The calling thread's CPU affinity mask (1024-bit, as 16 × u64) —
/// lets tests assert that single-thread and pooled runs leave the
/// caller's placement untouched. `None` off Linux or on error.
#[cfg(all(target_os = "linux", not(miri)))]
pub fn current_affinity() -> Option<[u64; 16]> {
    let mut mask = [0u64; 16];
    // SAFETY: a properly sized, writable mask for self (pid 0).
    let r = unsafe { ffi::sched_getaffinity(0, std::mem::size_of_val(&mask), mask.as_mut_ptr()) };
    if r == 0 { Some(mask) } else { None }
}

/// The calling thread's CPU affinity mask (`None` off Linux).
#[cfg(any(not(target_os = "linux"), miri))]
pub fn current_affinity() -> Option<[u64; 16]> {
    None
}

/// Which threads of a scoped team get pinned (always round-robin,
/// always gated on the host having a core per thread).
#[derive(Clone, Copy, PartialEq, Eq)]
enum TeamPin {
    /// Nobody pins.
    None,
    /// Spawned tids `1..p` pin; the calling thread (tid 0) keeps its
    /// affinity.
    Workers,
    /// Everyone pins, caller included (tid 0 → core 0).
    All,
}

/// One scoped fork-join over `p` threads with the given pin mode —
/// the single implementation behind [`scoped_run`] and
/// [`scoped_run_pin_workers`], so the spawn loop, the
/// `num_cpus() >= p` gate, and the `p == 1` shortcut cannot drift
/// between the two.
fn scoped_run_with_pin<F>(p: usize, pin: TeamPin, f: F)
where
    F: Fn(usize) + Sync,
{
    assert!(p > 0, "need at least one worker");
    let do_pin = pin != TeamPin::None && num_cpus() >= p;
    if p == 1 {
        if do_pin && pin == TeamPin::All {
            pin_to_cpu(0);
        }
        f(0);
        return;
    }
    std::thread::scope(|s| {
        for tid in 1..p {
            let f = &f;
            s.spawn(move || {
                if do_pin {
                    pin_to_cpu(tid);
                }
                f(tid);
            });
        }
        if do_pin && pin == TeamPin::All {
            pin_to_cpu(0);
        }
        f(0); // caller participates as thread 0
    });
}

/// Run `f(tid)` on `p` freshly spawned scoped threads and wait for all
/// of them. Threads are pinned round-robin when the host has enough
/// cores. This pays a spawn+join per call — prefer the persistent
/// pool ([`super::runtime::Runtime`]) for repeated short loops.
pub fn scoped_run<F>(p: usize, pin: bool, f: F)
where
    F: Fn(usize) + Sync,
{
    scoped_run_with_pin(p, if pin { TeamPin::All } else { TeamPin::None }, f);
}

/// Like [`scoped_run`] with pinning applied to the *spawned* threads
/// only: tids `1..p` are pinned round-robin (when the host has a core
/// per thread) while the calling thread — tid 0 — keeps its affinity
/// untouched. This is the per-run pinning policy of the pool's
/// oversized-run fallback ([`super::runtime::SubmitOpts::pin_fallback`]):
/// the caller's placement belongs to whoever pinned it (the pool's
/// spawn-time map, a `taskset`, nobody), so a transient team must
/// never re-pin it, but its own short-lived members may still honor
/// `ForOpts::pin`.
pub fn scoped_run_pin_workers<F>(p: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    scoped_run_with_pin(p, TeamPin::Workers, f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn num_cpus_positive_and_stable() {
        assert!(num_cpus() >= 1);
        assert_eq!(num_cpus(), num_cpus()); // cached
    }

    #[test]
    fn all_tids_run_once() {
        let p = 8;
        let hits: Vec<AtomicUsize> = (0..p).map(|_| AtomicUsize::new(0)).collect();
        scoped_run(p, false, |tid| {
            hits[tid].fetch_add(1, Ordering::SeqCst);
        });
        for (tid, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "tid {tid}");
        }
    }

    #[test]
    fn single_thread_runs_inline() {
        let hit = AtomicUsize::new(0);
        scoped_run(1, false, |tid| {
            assert_eq!(tid, 0);
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn pinning_does_not_crash() {
        scoped_run(2, true, |_tid| {
            std::hint::black_box(1 + 1);
        });
    }

    #[test]
    fn pin_workers_variant_covers_and_never_pins_the_caller() {
        let before = current_affinity();
        let p = 4;
        let hits: Vec<AtomicUsize> = (0..p).map(|_| AtomicUsize::new(0)).collect();
        scoped_run_pin_workers(p, |tid| {
            hits[tid].fetch_add(1, Ordering::SeqCst);
        });
        for (tid, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "tid {tid}");
        }
        if let Some(b) = before {
            assert_eq!(current_affinity().unwrap(), b, "caller affinity must survive the pinned team");
        }
    }

    #[test]
    fn pinned_core_tracks_successful_pins() {
        std::thread::scope(|s| {
            s.spawn(|| {
                assert_eq!(pinned_core(), None, "fresh thread starts unpinned");
                pin_to_cpu(0);
                // Only assert when the pin observably took effect (it
                // is best-effort under restricted affinity masks).
                if current_affinity().is_some_and(|m| m[0] & 1 == 1) {
                    assert_eq!(pinned_core(), Some(0), "successful pin must be recorded");
                }
            });
        });
    }

    #[test]
    fn affinity_reads_back_after_pin() {
        // Pin a throwaway scoped thread (not the test runner's thread)
        // and read its mask back.
        std::thread::scope(|s| {
            s.spawn(|| {
                pin_to_cpu(0);
                if let Some(mask) = current_affinity() {
                    assert_eq!(mask[0] & 1, 1, "pinned thread must include core 0");
                    let ones: u32 = mask.iter().map(|w| w.count_ones()).sum();
                    assert_eq!(ones, 1, "pin_to_cpu leaves exactly one allowed core");
                }
            });
        });
    }
}
