//! Worker threads with optional core pinning.
//!
//! The paper pins OpenMP threads to cores (`OMP_PROC_BIND=true`,
//! `OMP_PLACES=cores`). We do the same via `sched_setaffinity` when
//! the machine has at least as many cores as requested threads;
//! otherwise (e.g. this 1-core container) pinning is skipped — the
//! schedulers remain correct, merely oversubscribed.

/// Number of online CPUs.
pub fn num_cpus() -> usize {
    // SAFETY: sysconf is async-signal-safe and has no memory effects.
    let n = unsafe { libc::sysconf(libc::_SC_NPROCESSORS_ONLN) };
    if n <= 0 { 1 } else { n as usize }
}

/// Pin the calling thread to `cpu` (best-effort; errors ignored).
pub fn pin_to_cpu(cpu: usize) {
    // SAFETY: CPU_SET/sched_setaffinity with a properly zeroed set.
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_SET(cpu % num_cpus(), &mut set);
        libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set);
    }
}

/// Run `f(tid)` on `p` scoped worker threads and wait for all of them.
/// Threads are pinned round-robin when the host has enough cores.
pub fn scoped_run<F>(p: usize, pin: bool, f: F)
where
    F: Fn(usize) + Sync,
{
    assert!(p > 0, "need at least one worker");
    let do_pin = pin && num_cpus() >= p;
    if p == 1 {
        if do_pin {
            pin_to_cpu(0);
        }
        f(0);
        return;
    }
    std::thread::scope(|s| {
        for tid in 1..p {
            let f = &f;
            s.spawn(move || {
                if do_pin {
                    pin_to_cpu(tid);
                }
                f(tid);
            });
        }
        if do_pin {
            pin_to_cpu(0);
        }
        f(0); // caller participates as thread 0
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn num_cpus_positive() {
        assert!(num_cpus() >= 1);
    }

    #[test]
    fn all_tids_run_once() {
        let p = 8;
        let hits: Vec<AtomicUsize> = (0..p).map(|_| AtomicUsize::new(0)).collect();
        scoped_run(p, false, |tid| {
            hits[tid].fetch_add(1, Ordering::SeqCst);
        });
        for (tid, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "tid {tid}");
        }
    }

    #[test]
    fn single_thread_runs_inline() {
        let hit = AtomicUsize::new(0);
        scoped_run(1, false, |tid| {
            assert_eq!(tid, 0);
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn pinning_does_not_crash() {
        scoped_run(2, true, |_tid| {
            std::hint::black_box(1 + 1);
        });
    }
}
