//! Discrete-event simulated testbed.
//!
//! The paper evaluates on a 2-socket × 14-core Haswell node; this
//! container has one core, so wall-clock 28-thread speedups are
//! unobtainable here. Instead, the speedup experiments run the *same
//! scheduling algorithms* (shared math in `sched::policy`) over the
//! same workload traces on a simulated machine with a calibrated cost
//! model — which is exactly what determines the paper's speedup
//! *shapes* (DESIGN.md §3 documents this substitution).

pub mod engine;
pub mod machine;
pub mod policies;

pub use engine::{Acquire, LoopSpec, SimCtx, SimResult, SimSched};
pub use machine::MachineSpec;
pub use policies::{
    make_assist_sim_policy, make_sim_policy, sim_dispatch_order, sim_dispatch_order_from, sim_fair_order, AssistSim,
    AutoSim, SimArrival, SimFairArrival, SimFairOutcome, SimTenantSpec,
};

use crate::sched::Policy;

/// Simulate an application = an ordered sequence of parallel loops
/// (fork-join regions). Each loop gets a fresh policy instance, as a
/// fresh `parallel_for` would in libgomp.
pub fn simulate_app(
    spec: &MachineSpec,
    p: usize,
    loops: &[LoopSpec],
    policy: &Policy,
    seed: u64,
) -> SimResult {
    if matches!(policy, Policy::Auto) {
        // Selector state persists across the app's loops (a repeated
        // inner loop converges within one app run). For learning that
        // persists across *episodes* — the regret harness — hold an
        // [`AutoSim`] and call `run_app` on it repeatedly.
        let mut auto_sim = AutoSim::new(crate::sched::auto::AutoConfig::default());
        return auto_sim.run_app(spec, p, loops, seed);
    }
    let mut total = SimResult::default();
    for (li, ls) in loops.iter().enumerate() {
        let mut pol = make_sim_policy(policy, &ls.weights, p);
        let r = engine::simulate_loop(spec, p, ls, seed.wrapping_add(li as u64), pol.as_mut());
        total.absorb(&r);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::IchParams;

    #[test]
    fn app_with_multiple_loops_accumulates() {
        let spec = MachineSpec::default();
        let loops = vec![
            LoopSpec::new(vec![10.0; 100], 0.0),
            LoopSpec::new(vec![5.0; 200], 0.0),
        ];
        let one = simulate_app(&spec, 4, &loops[..1], &Policy::Ich(IchParams::default()), 1);
        let both = simulate_app(&spec, 4, &loops, &Policy::Ich(IchParams::default()), 1);
        assert!(both.time > one.time);
        assert_eq!(both.iters_per_thread.iter().sum::<u64>(), 300);
    }

    #[test]
    fn speedup_is_sane_for_all_paper_policies() {
        // A well-balanced compute loop: every paper policy should see
        // meaningful speedup from 1 to 14 threads on the simulator.
        let spec = MachineSpec::default();
        let loops = vec![LoopSpec::new(vec![200.0; 2000], 0.0)];
        for fam in crate::sched::PAPER_FAMILIES {
            let pol = crate::sched::table2_grid(fam).remove(0);
            let t1 = simulate_app(&spec, 1, &loops, &pol, 1).time;
            let t14 = simulate_app(&spec, 14, &loops, &pol, 1).time;
            let sp = t1 / t14;
            assert!(sp > 6.0, "family {fam}: speedup(14) = {sp:.2}");
        }
    }
}
