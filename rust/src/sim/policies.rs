//! Virtual-time implementations of every scheduling policy, reusing
//! the shared math in `sched::policy` so the simulator runs the *same*
//! algorithm as the threaded runtime — only the execution substrate
//! (virtual clock + cost model vs. real atomics) differs.

use super::engine::{simulate_loop, Acquire, LoopSpec, SimCtx, SimResult, SimSched};
use super::machine::MachineSpec;
use crate::sched::policy::{self, IchState};
use crate::sched::topology::{self, VictimPolicy, VictimSelector};
use crate::sched::ws::{IchParams, StealMerge};
use crate::sched::{auto, features, Policy};

/// Build the sim-side policy object for one loop.
pub fn make_sim_policy(policy: &Policy, weights: &[f64], p: usize) -> Box<dyn SimSched> {
    let n = weights.len();
    match policy {
        // One-shot fallback: a single fresh loop has no history to
        // learn from, so `auto` resolves to its cold-start arm (the
        // arms never contain `Auto`, so this recurses exactly once).
        // Learning across loops and episodes lives in [`AutoSim`].
        Policy::Auto => {
            let arms = auto::arms();
            make_sim_policy(&arms[auto::cold_hint(arms, n, p.max(1), true)], weights, p)
        }
        Policy::Static => Box::new(ChunkListSim::local(policy::static_blocks(n, p), p)),
        Policy::Dynamic { chunk } => Box::new(CentralSim::dynamic(n, *chunk)),
        Policy::Guided { chunk } => Box::new(CentralSim::guided(n, *chunk)),
        Policy::Taskloop { num_tasks } => {
            let t = if *num_tasks == 0 { p } else { *num_tasks };
            Box::new(ChunkListSim::central_with_task_overhead(policy::taskloop_chunks(n, t)))
        }
        Policy::Factoring { alpha } => Box::new(ChunkListSim::central(policy::factoring_chunks(n, p, *alpha))),
        Policy::Binlpt { max_chunks } => Box::new(BinlptSim::new(weights, *max_chunks, p)),
        Policy::Stealing { chunk } => Box::new(WsSim::fixed(n, p, *chunk)),
        Policy::Ich(prm) => Box::new(WsSim::adaptive(n, p, *prm)),
        Policy::Awf => Box::new(AwfSim::new(n, p)),
        Policy::Hss => Box::new(ChunkListSim::local(crate::sched::related::weighted_blocks(weights, p), p)),
    }
}

/// Build the sim-side mirror of a work-assisted loop: `p` members run
/// the policy from virtual time 0 and `arrive.len()` assist joiners
/// enter at the given virtual times (simulate with
/// `p + arrive.len()` threads). Mirrors the runtime's assist layer:
/// a joiner that arrives after the loop has finished backs out
/// without joining, tid-indexed policy state is padded so joiners own
/// real deque/history slots, and non-assistable policies (`static`,
/// `hss`) give joiners nothing — exactly like the real engines.
pub fn make_assist_sim_policy(policy: &Policy, weights: &[f64], p: usize, arrive: &[f64]) -> Box<dyn SimSched> {
    let n = weights.len();
    if matches!(policy, Policy::Auto) {
        // Same one-shot cold-start resolution as `make_sim_policy`.
        let arms = auto::arms();
        let arm = arms[auto::cold_hint(arms, n, p.max(1), true)].clone();
        return make_assist_sim_policy(&arm, weights, p, arrive);
    }
    let slots = p + arrive.len();
    let inner: Box<dyn SimSched> = match policy {
        Policy::Static => Box::new(ChunkListSim::local(policy::static_blocks(n, p), slots)),
        Policy::Dynamic { chunk } => Box::new(CentralSim::dynamic(n, *chunk)),
        Policy::Guided { chunk } => Box::new(CentralSim::guided(n, *chunk)),
        Policy::Taskloop { num_tasks } => {
            let t = if *num_tasks == 0 { p } else { *num_tasks };
            Box::new(ChunkListSim::central_with_task_overhead(policy::taskloop_chunks(n, t)))
        }
        Policy::Factoring { alpha } => Box::new(ChunkListSim::central(policy::factoring_chunks(n, p, *alpha))),
        Policy::Binlpt { max_chunks } => Box::new(BinlptSim::new(weights, *max_chunks, p).padded(slots)),
        Policy::Stealing { chunk } => Box::new(WsSim::fixed(n, p, *chunk).padded(slots)),
        Policy::Ich(prm) => Box::new(WsSim::adaptive(n, p, *prm).padded(slots)),
        Policy::Awf => Box::new(AwfSim::new(n, slots)),
        Policy::Hss => Box::new(ChunkListSim::local(crate::sched::related::weighted_blocks(weights, p), slots)),
        Policy::Auto => unreachable!("resolved to a fixed arm above"),
    };
    Box::new(AssistSim::new(inner, p, arrive.to_vec()))
}

/// Episode-persistent `Policy::Auto` in the simulator: the sim-side
/// mirror of the runtime coordinator's selector branch. Same arms
/// ([`auto::arms`]), same pick arithmetic ([`auto::pick`] via
/// [`auto::AutoCore`]), same per-iteration cost normalization and
/// feature bucketing — only the cost unit differs (virtual time vs
/// nanoseconds; the selector is scale-free, so behavior matches).
/// Hold one `AutoSim` across repeated [`AutoSim::run_app`] calls to
/// model a long-running process re-dispatching its loops: that is
/// exactly what the regret harness (`harness::regret`) measures.
pub struct AutoSim {
    cfg: auto::AutoConfig,
    core: auto::AutoCore,
    /// Arm chosen at each loop dispatch, in order — the differential
    /// tests and the harness's arm histogram read this log.
    pub chosen: Vec<usize>,
}

impl AutoSim {
    pub fn new(cfg: auto::AutoConfig) -> AutoSim {
        AutoSim { cfg, core: auto::AutoCore::new(), chosen: Vec::new() }
    }

    /// Read-only view of the selector state.
    pub fn core(&self) -> &auto::AutoCore {
        &self.core
    }

    /// The loop-site key the simulator assigns the `li`-th loop of an
    /// app: the loop index stands in for the runtime's callsite hash
    /// (the li-th source loop is the same loop every episode), and
    /// the trip count buckets exactly like the runtime's key.
    pub fn sim_site(li: usize, n: usize) -> features::SiteKey {
        features::site_key(features::mix64(0x5EED_A070 ^ li as u64), n.max(1))
    }

    /// Simulate one episode (one full app run) under `Policy::Auto`,
    /// persisting selector state across loops and episodes.
    pub fn run_app(&mut self, spec: &MachineSpec, p: usize, loops: &[LoopSpec], seed: u64) -> SimResult {
        let arms = auto::arms();
        let mut total = SimResult::default();
        for (li, ls) in loops.iter().enumerate() {
            let n = ls.weights.len();
            let site = AutoSim::sim_site(li, n);
            let cold = auto::cold_hint(arms, n, p.max(1), true);
            let choice = self.core.choose(site, &self.cfg, arms.len(), cold);
            self.chosen.push(choice.arm);
            let mut pol = make_sim_policy(&arms[choice.arm], &ls.weights, p);
            let r = simulate_loop(spec, p, ls, seed.wrapping_add(li as u64), pol.as_mut());
            let per_iter = r.time / n.max(1) as f64;
            self.core.observe(&choice, auto::quantize(per_iter));
            self.core.note_bucket(site, features::FeatureVec::extract_sim(&r, n, p).bucket());
            total.absorb(&r);
        }
        total
    }
}

/// Work-assist wrapper: gates joiner tids (`>= base_p`) behind their
/// virtual arrival time, then delegates to the wrapped policy. The
/// join/finish race resolves exactly like the runtime's gate: a
/// joiner observing the loop already complete returns `Done` without
/// ever registering as an assist.
pub struct AssistSim {
    inner: Box<dyn SimSched>,
    base_p: usize,
    arrive: Vec<f64>,
    joined: Vec<bool>,
    /// Joiners that actually entered (the sim's `RunMetrics::assists`).
    pub assists: u64,
}

impl AssistSim {
    pub fn new(inner: Box<dyn SimSched>, base_p: usize, arrive: Vec<f64>) -> AssistSim {
        let joined = vec![false; arrive.len()];
        AssistSim { inner, base_p, arrive, joined, assists: 0 }
    }
}

impl SimSched for AssistSim {
    fn acquire(&mut self, tid: usize, now: f64, ctx: &mut SimCtx) -> Acquire {
        if tid >= self.base_p {
            let s = tid - self.base_p;
            if ctx.executed >= ctx.n {
                // Lost the finish race (or the loop ended before the
                // arrival): back out without joining.
                return Acquire::Done;
            }
            if now < self.arrive[s] {
                return Acquire::Busy { until: self.arrive[s] };
            }
            if !self.joined[s] {
                self.joined[s] = true;
                self.assists += 1;
                // The runtime registers a joiner in the participant
                // divisor before it executes its first chunk
                // (`Shared::register_joiner`); the sim mirror is this
                // forward.
                self.inner.notify_join(tid);
            }
        }
        self.inner.acquire(tid, now, ctx)
    }

    fn on_complete(&mut self, tid: usize, lo: usize, hi: usize, now: f64, ctx: &mut SimCtx) {
        self.inner.on_complete(tid, lo, hi, now, ctx)
    }
}

// ---------------------------------------------------------------------------
// Central-queue policies (dynamic / guided)
// ---------------------------------------------------------------------------

enum CentralMode {
    Dynamic { chunk: usize },
    Guided { min_chunk: usize },
}

/// `dynamic` / `guided`: one shared counter; every grab serializes on
/// the central queue server.
struct CentralSim {
    n: usize,
    next: usize,
    mode: CentralMode,
}

impl CentralSim {
    fn dynamic(n: usize, chunk: usize) -> CentralSim {
        CentralSim { n, next: 0, mode: CentralMode::Dynamic { chunk: chunk.max(1) } }
    }

    fn guided(n: usize, min_chunk: usize) -> CentralSim {
        CentralSim { n, next: 0, mode: CentralMode::Guided { min_chunk } }
    }
}

impl SimSched for CentralSim {
    fn acquire(&mut self, _tid: usize, now: f64, ctx: &mut SimCtx) -> Acquire {
        if self.next >= self.n {
            return Acquire::Done;
        }
        let c = match self.mode {
            CentralMode::Dynamic { chunk } => chunk,
            CentralMode::Guided { min_chunk } => policy::guided_chunk(self.n - self.next, ctx.p, min_chunk),
        }
        .min(self.n - self.next);
        let lo = self.next;
        self.next += c;
        let overhead = ctx.central_op(now, ctx.spec.c_dispatch_central, ctx.spec.c_central_serial);
        Acquire::Chunk { lo, hi: lo + c, overhead }
    }
}

// ---------------------------------------------------------------------------
// Precomputed chunk lists (static / taskloop / factoring / HSS)
// ---------------------------------------------------------------------------

/// Executes a precomputed chunk list. Three flavors:
/// - `local`: chunk i belongs to thread i (static/HSS); no shared queue.
/// - `central`: chunks claimed from a central counter (factoring).
/// - `central_with_task_overhead`: like central plus OpenMP task-creation
///   cost per task (taskloop).
struct ChunkListSim {
    chunks: Vec<(usize, usize)>,
    next: usize,
    /// Thread-owned (static-like) instead of centrally claimed.
    owned: bool,
    /// Extra per-chunk creation overhead (taskloop).
    task_overhead: bool,
    /// For owned mode: has thread t run its chunk yet?
    ran: Vec<bool>,
}

impl ChunkListSim {
    fn local(chunks: Vec<(usize, usize)>, p: usize) -> ChunkListSim {
        ChunkListSim { chunks, next: 0, owned: true, task_overhead: false, ran: vec![false; p] }
    }

    fn central(chunks: Vec<(usize, usize)>) -> ChunkListSim {
        ChunkListSim { chunks, next: 0, owned: false, task_overhead: false, ran: Vec::new() }
    }

    fn central_with_task_overhead(chunks: Vec<(usize, usize)>) -> ChunkListSim {
        ChunkListSim { chunks, next: 0, owned: false, task_overhead: true, ran: Vec::new() }
    }
}

impl SimSched for ChunkListSim {
    fn acquire(&mut self, tid: usize, now: f64, ctx: &mut SimCtx) -> Acquire {
        if self.owned {
            if self.ran[tid] {
                return Acquire::Done;
            }
            self.ran[tid] = true;
            match self.chunks.get(tid) {
                Some(&(lo, hi)) if lo < hi => {
                    Acquire::Chunk { lo, hi, overhead: ctx.spec.c_dispatch_local }
                }
                _ => Acquire::Done,
            }
        } else {
            if self.next >= self.chunks.len() {
                return Acquire::Done;
            }
            let (lo, hi) = self.chunks[self.next];
            self.next += 1;
            let mut overhead = ctx.central_op(now, ctx.spec.c_dispatch_central, ctx.spec.c_central_serial);
            if self.task_overhead {
                overhead += ctx.spec.c_task_create;
            }
            Acquire::Chunk { lo, hi, overhead }
        }
    }
}

// ---------------------------------------------------------------------------
// BinLPT
// ---------------------------------------------------------------------------

/// BinLPT: LPT-assigned chunk lists per thread, then a claim-anything
/// rebalance phase through the central queue.
struct BinlptSim {
    chunks: Vec<(usize, usize)>,
    assign: Vec<Vec<usize>>,
    claimed: Vec<bool>,
    /// Next index into the thread's own assignment list.
    own_pos: Vec<usize>,
    /// Next index into the global chunk list for phase 2.
    scan: usize,
}

impl BinlptSim {
    fn new(weights: &[f64], max_chunks: usize, p: usize) -> BinlptSim {
        let (chunks, assign) = policy::binlpt_partition(weights, max_chunks, p);
        let nchunks = chunks.len();
        BinlptSim { chunks, assign, claimed: vec![false; nchunks], own_pos: vec![0; p], scan: 0 }
    }

    /// Widen the tid-indexed state for assist joiners: joiner tids own
    /// an empty LPT assignment, so they enter straight at phase 2 —
    /// exactly like the runtime's BinLPT joiners.
    fn padded(mut self, slots: usize) -> BinlptSim {
        self.assign.resize(slots, Vec::new());
        self.own_pos.resize(slots, 0);
        self
    }
}

impl SimSched for BinlptSim {
    fn acquire(&mut self, tid: usize, now: f64, ctx: &mut SimCtx) -> Acquire {
        // Phase 1: own list (local dispatch — the queue is thread-local).
        while let Some(&ci) = self.assign[tid].get(self.own_pos[tid]) {
            self.own_pos[tid] += 1;
            if !self.claimed[ci] {
                self.claimed[ci] = true;
                let (lo, hi) = self.chunks[ci];
                return Acquire::Chunk { lo, hi, overhead: ctx.spec.c_dispatch_local };
            }
        }
        // Phase 2: claim any unstarted chunk (goes through the shared
        // claim array — serialize like a central queue op).
        while self.scan < self.chunks.len() {
            let ci = self.scan;
            if self.claimed[ci] {
                self.scan += 1;
                continue;
            }
            self.claimed[ci] = true;
            let (lo, hi) = self.chunks[ci];
            let overhead = ctx.central_op(now, ctx.spec.c_dispatch_central, ctx.spec.c_central_serial);
            return Acquire::Chunk { lo, hi, overhead };
        }
        Acquire::Done
    }
}

// ---------------------------------------------------------------------------
// AWF
// ---------------------------------------------------------------------------

/// Adaptive Weighted Factoring: central queue; chunk scaled by the
/// thread's measured relative speed (which, in the simulator, converges
/// to the core's true speed factor — modeled directly after the first
/// completed chunk).
struct AwfSim {
    n: usize,
    next: usize,
    measured: Vec<Option<f64>>,
}

impl AwfSim {
    fn new(n: usize, p: usize) -> AwfSim {
        AwfSim { n, next: 0, measured: vec![None; p] }
    }
}

impl SimSched for AwfSim {
    fn acquire(&mut self, tid: usize, now: f64, ctx: &mut SimCtx) -> Acquire {
        if self.next >= self.n {
            return Acquire::Done;
        }
        let w = self.measured[tid].unwrap_or(1.0).clamp(0.25, 4.0);
        let base = policy::guided_chunk(self.n - self.next, 2 * ctx.p, 1);
        let c = (((base as f64) * w) as usize).max(1).min(self.n - self.next);
        let lo = self.next;
        self.next += c;
        let overhead = ctx.central_op(now, ctx.spec.c_dispatch_central, ctx.spec.c_central_serial);
        Acquire::Chunk { lo, hi: lo + c, overhead }
    }

    fn on_complete(&mut self, tid: usize, _lo: usize, _hi: usize, _now: f64, ctx: &mut SimCtx) {
        // After one chunk the thread "knows" its throughput relative to
        // the mean; the sim shortcuts the measurement with the true
        // core speed (what AWF's estimator converges to).
        let speeds = ctx.spec.core_speeds(ctx.p, 0);
        let mean = speeds.iter().sum::<f64>() / ctx.p as f64;
        self.measured[tid] = Some(speeds[tid] / mean);
    }
}

// ---------------------------------------------------------------------------
// Work stealing: fixed-chunk `stealing` and adaptive iCh
// ---------------------------------------------------------------------------

enum WsMode {
    Fixed(usize),
    Adaptive(IchParams),
}

/// Virtual-time mirror of `sched::ws`: per-thread ranges, owner-side
/// dispatch, half-stealing with the runtime's two-tier victim
/// selection, and (for iCh) the adaptive chunk logic from
/// `sched::policy`.
struct WsSim {
    mode: WsMode,
    /// Per-thread remaining range [begin, end).
    deques: Vec<(usize, usize)>,
    states: Vec<IchState>,
    /// Consecutive failed steals per thread (backoff).
    fails: Vec<u32>,
    /// Per-thief two-tier victim selection, shared with the real
    /// engines (`sched::topology`) so the two runtimes cannot drift.
    sel: Vec<VictimSelector>,
    /// tid → socket, cached from the machine spec on first steal.
    sockets: Vec<usize>,
    /// Victim policy, resolved from the process-wide knob (CLI
    /// `--steal` / `ICH_STEAL`) — the same default every
    /// `ForOpts::default()` resolves to, so the sim follows the
    /// runtime when the user switches to uniform stealing.
    victim: VictimPolicy,
    /// Threads currently in the μ divisor: the base members plus every
    /// assist joiner that has actually entered (`notify_join`). The
    /// runtime mirror is `ws::Shared::participants` — dividing by the
    /// padded slot count instead would deflate μ with slots whose k is
    /// still 0 because the joiner never arrived.
    active: usize,
}

impl WsSim {
    fn fixed(n: usize, p: usize, chunk: usize) -> WsSim {
        WsSim::new(n, p, WsMode::Fixed(chunk.max(1)))
    }

    fn adaptive(n: usize, p: usize, prm: IchParams) -> WsSim {
        WsSim::new(n, p, WsMode::Adaptive(prm))
    }

    fn new(n: usize, p: usize, mode: WsMode) -> WsSim {
        WsSim::with_victim(n, p, mode, VictimPolicy::process_default())
    }

    /// Explicit-victim constructor (tests pin `Ranked`/`Uniform`
    /// without touching the process-wide default).
    fn with_victim(n: usize, p: usize, mode: WsMode, victim: VictimPolicy) -> WsSim {
        let blocks = policy::static_blocks(n, p);
        let mut deques: Vec<(usize, usize)> = blocks;
        while deques.len() < p {
            deques.push((0, 0));
        }
        let d0 = match &mode {
            WsMode::Adaptive(prm) => prm.d0.unwrap_or(p as f64).max(policy::D_MIN),
            WsMode::Fixed(_) => policy::D_MIN,
        };
        let _ = n;
        WsSim {
            mode,
            deques,
            states: vec![IchState { k: 0.0, d: d0 }; p],
            fails: vec![0; p],
            sel: (0..p).map(|_| VictimSelector::new()).collect(),
            sockets: Vec::new(),
            victim,
            active: p,
        }
    }

    /// Widen the tid-indexed state for assist joiners: joiner tids own
    /// empty deques (they steal their first range) and fresh adaptive
    /// state at d₀ — mirroring the runtime's `Shared::new` extra slots.
    fn padded(mut self, slots: usize) -> WsSim {
        let d0 = self.states.first().map_or(policy::D_MIN, |s| s.d);
        while self.deques.len() < slots {
            self.deques.push((0, 0));
        }
        self.states.resize(slots, IchState { k: 0.0, d: d0 });
        self.fails.resize(slots, 0);
        while self.sel.len() < slots {
            self.sel.push(VictimSelector::new());
        }
        self
    }

    fn remaining(&self, tid: usize) -> usize {
        self.deques[tid].1 - self.deques[tid].0
    }

    /// §3.2 mean progress over the threads actually participating —
    /// identical to `ws::Shared::mu()`'s done/participants once the
    /// joiners' samples are folded in (pinned by the checker's
    /// `mu_merge` model and `ws_mu_divisor_tracks_joined_threads`).
    fn mu(&self) -> f64 {
        self.states.iter().map(|s| s.k).sum::<f64>() / self.active as f64
    }

    fn chunk_for(&self, tid: usize) -> usize {
        match &self.mode {
            WsMode::Fixed(c) => *c,
            WsMode::Adaptive(_) => policy::ich_chunk(self.remaining(tid).max(1), self.states[tid].d),
        }
    }
}

impl SimSched for WsSim {
    fn acquire(&mut self, tid: usize, now: f64, ctx: &mut SimCtx) -> Acquire {
        // Own queue first.
        let rem = self.remaining(tid);
        if rem > 0 {
            let c = self.chunk_for(tid).max(1).min(rem);
            let lo = self.deques[tid].0;
            self.deques[tid].0 += c;
            self.fails[tid] = 0;
            // iCh pays the adaptation pass on each dispatch (reads p
            // counters + classification).
            let adapt_cost = match &self.mode {
                WsMode::Adaptive(_) => ctx.spec.c_adapt_base + ctx.spec.c_adapt_per_thread * ctx.p as f64,
                WsMode::Fixed(_) => 0.0,
            };
            return Acquire::Chunk { lo, hi: lo + c, overhead: ctx.spec.c_dispatch_local + adapt_cost };
        }

        // Terminate once everything has been *executed* (threads spin
        // while the last chunks are in flight, as in the real runtime).
        if ctx.executed >= ctx.n {
            return Acquire::Done;
        }
        if ctx.p == 1 {
            return Acquire::Busy { until: now + ctx.spec.c_steal_fail };
        }

        // Steal attempt (§3.3). Victim selection is aligned with the
        // real runtime (`sched::ws`): on multi-socket machines with
        // p > 2, `Topo` runs the two-tier bias and `Ranked` the
        // distance-ranked multi-tier bias over the machine's
        // socket-distance matrix (gated off when the matrix is
        // equidistant, exactly like the real engines gate on
        // `Topology::is_equidistant`); the paper's uniform draw
        // otherwise — the same gates, constants, and fallback rule
        // via the shared `VictimSelector` and `uniform_victim`.
        if self.sockets.is_empty() {
            self.sockets = (0..ctx.p).map(|t| ctx.socket_of(t)).collect();
        }
        let multi = ctx.spec.sockets > 1 && ctx.p > 2;
        let (v, was_local) = match self.victim {
            VictimPolicy::Topo if multi => {
                let socks = &self.sockets;
                self.sel[tid].pick(tid, ctx.p, Some(socks[tid]), |t| Some(socks[t]), &mut ctx.rng)
            }
            VictimPolicy::Ranked if multi && !ctx.spec.is_equidistant() => {
                let spec = ctx.spec;
                let socks = &self.sockets;
                self.sel[tid].pick_ranked(
                    tid,
                    ctx.p,
                    Some(socks[tid]),
                    |t| Some(socks[t]),
                    |a, b| spec.node_distance(a, b),
                    &mut ctx.rng,
                )
            }
            _ => {
                let v = topology::uniform_victim(tid, ctx.p, &mut ctx.rng);
                (v, self.sockets[v] == self.sockets[tid])
            }
        };
        let vrem = self.remaining(v);
        if vrem == 0 {
            ctx.steals_fail += 1;
            self.sel[tid].record(false, was_local);
            self.fails[tid] = (self.fails[tid] + 1).min(6);
            // Exponential backoff keeps the event count bounded while
            // matching real spin-with-pause behaviour.
            let backoff = ctx.spec.c_steal_fail * f64::from(1u32 << self.fails[tid]);
            return Acquire::Busy { until: now + backoff };
        }
        // Steal half through the victim's queue lock; cross-socket
        // steals pay the distance-ratio multiplier (1.0 on-socket,
        // distance[a][b]/distance[a][a] across — 2.5 under the
        // default matrix, the model's historical calibration).
        let numa = ctx.spec.steal_mult(self.sockets[tid], self.sockets[v]);
        let cost = ctx.queue_op(v, now, ctx.spec.c_steal_ok * numa, ctx.spec.c_steal_serial * numa);
        let half = vrem.div_ceil(2);
        let ne = self.deques[v].1 - half;
        let stolen = (ne, self.deques[v].1);
        self.deques[v].1 = ne;
        self.deques[tid] = stolen;
        ctx.steals_ok += 1;
        if was_local {
            ctx.steals_local += 1;
        }
        self.sel[tid].record(true, was_local);
        self.fails[tid] = 0;
        if let WsMode::Adaptive(prm) = &self.mode {
            let merged = match prm.merge {
                StealMerge::Average => policy::steal_merge(self.states[tid], self.states[v]),
                StealMerge::Victim => self.states[v],
                StealMerge::Keep => self.states[tid],
            };
            self.states[tid] = merged;
            // Listing 1 lines 20–22, sized on the victim's pre-steal
            // queue (see `policy::clamp_chunk_to_stolen`).
            self.states[tid].d = policy::clamp_chunk_to_stolen(half, vrem, self.states[tid].d);
        }
        // Per Listing 1 the thief immediately starts on the stolen
        // range (lines 23–24 set begin/end and the thread proceeds to
        // execute). Dispatching here — with the steal latency folded
        // into the chunk's overhead — also prevents the degenerate
        // mutual-re-steal livelock a pure "steal then re-acquire"
        // model exhibits at p=2 on a 1-iteration remainder.
        let c = self.chunk_for(tid).max(1).min(half);
        let lo = self.deques[tid].0;
        self.deques[tid].0 += c;
        Acquire::Chunk { lo, hi: lo + c, overhead: cost + ctx.spec.c_dispatch_local }
    }

    fn on_complete(&mut self, tid: usize, lo: usize, hi: usize, _now: f64, _ctx: &mut SimCtx) {
        let st = &mut self.states[tid];
        st.k += (hi - lo) as f64;
        if let WsMode::Adaptive(prm) = &self.mode {
            // §3.2: classify against μ ± δ over the participating
            // threads' k (joiners enter the divisor via notify_join).
            let mu = self.mu();
            let delta = policy::delta(prm.eps, mu);
            let st = &mut self.states[tid];
            let class = policy::classify(st.k, mu, delta);
            st.d = if prm.inverted { policy::adapt_inverted(st.d, class) } else { policy::adapt(st.d, class) };
        }
    }

    fn notify_join(&mut self, _tid: usize) {
        // Fired at most once per joiner (AssistSim's joined[] guard);
        // capped defensively at the padded slot count.
        self.active = (self.active + 1).min(self.states.len());
    }
}

// ---------------------------------------------------------------------------
// Multi-class dispatch model (runtime epoch queue)
// ---------------------------------------------------------------------------

/// One epoch arrival in a dispatch trace for [`sim_dispatch_order`].
/// `after` is the virtual arrival time measured in completed
/// dispatches: the entry is admitted once `after` earlier entries
/// have been dispatched (0 = present from the start). Traces must be
/// sorted by `after` — arrivals are admitted in slice order, which
/// is the arrival-sequence order the runtime's queue sees. `origin`
/// is the NUMA node the epoch was submitted from (`None` = unknown,
/// distance weight neutral), consumed by
/// [`sim_dispatch_order_from`]'s weighted EDF key.
#[derive(Clone, Copy, Debug)]
pub struct SimArrival {
    pub class: crate::sched::LatencyClass,
    pub deadline: Option<u64>,
    pub origin: Option<usize>,
    pub after: usize,
}

/// [`sim_dispatch_order_from`] with the neutral distance weight
/// (claimant unknown) — the pre-distance model.
pub fn sim_dispatch_order(arrivals: &[SimArrival], promote_k: u64) -> Vec<usize> {
    sim_dispatch_order_from(arrivals, promote_k, None, &|_, _| 0)
}

/// The simulator's *independent* model of the pool's multi-class
/// dispatch rule (`sched::dispatch`): class priority, EDF within a
/// class weighted by `excess(claimant_node, origin)` extra ticks
/// (neutral when the claimant or an entry's origin is unknown), FIFO
/// among equal-effective-deadline peers, and anti-starvation
/// promotion once an entry has been bypassed `promote_k` times by
/// later, higher-class arrivals. Returns the indices of `arrivals`
/// in dispatch order.
///
/// This is a deliberate re-implementation (O(n²) scan over a pending
/// list, no shared code with `DispatchQueue`) so the conformance
/// harness can differentially test the runtime against it.
pub fn sim_dispatch_order_from(
    arrivals: &[SimArrival],
    promote_k: u64,
    claimant_node: Option<usize>,
    excess: &dyn Fn(usize, usize) -> u64,
) -> Vec<usize> {
    struct Pending {
        idx: usize,
        rank: u8,
        deadline: u64,
        skips: u64,
    }
    let n = arrivals.len();
    let mut order: Vec<usize> = Vec::with_capacity(n);
    let mut pending: Vec<Pending> = Vec::new();
    let mut admitted = 0usize;
    let admit = |pending: &mut Vec<Pending>, i: usize| {
        let a = arrivals[i];
        // The weighted effective deadline is fixed per (claimant,
        // entry) pair, so it can be resolved at admission.
        let deadline = match (a.deadline, claimant_node, a.origin) {
            (None, _, _) => u64::MAX,
            (Some(d), Some(w), Some(o)) => d.saturating_add(excess(w, o)),
            (Some(d), _, _) => d,
        };
        pending.push(Pending { idx: i, rank: a.class.rank(), deadline, skips: 0 });
    };
    while order.len() < n {
        while admitted < n && arrivals[admitted].after <= order.len() {
            admit(&mut pending, admitted);
            admitted += 1;
        }
        if pending.is_empty() {
            // Idle gap in the trace: jump the virtual clock to the
            // next arrival batch.
            let next_after = arrivals[admitted].after;
            while admitted < n && arrivals[admitted].after == next_after {
                admit(&mut pending, admitted);
                admitted += 1;
            }
        }
        // Selection: earliest-arrived starving entry, else
        // (class rank, deadline, arrival).
        let mut best = 0usize;
        for i in 1..pending.len() {
            let (a, b) = (&pending[i], &pending[best]);
            let (a_starving, b_starving) = (a.skips >= promote_k, b.skips >= promote_k);
            let a_wins = match (a_starving, b_starving) {
                (true, false) => true,
                (false, true) => false,
                (true, true) => a.idx < b.idx,
                (false, false) => (a.rank, a.deadline, a.idx) < (b.rank, b.deadline, b.idx),
            };
            if a_wins {
                best = i;
            }
        }
        let sel = pending.remove(best);
        for e in &mut pending {
            if e.idx < sel.idx && e.rank > sel.rank {
                e.skips += 1;
            }
        }
        order.push(sel.idx);
    }
    order
}

// ---------------------------------------------------------------------------
// Multi-tenant fair-share model (runtime admission front end)
// ---------------------------------------------------------------------------

/// Static tenant parameters for [`sim_fair_order`] — the sim-side
/// mirror of `sched::fair::TenantSpec`, minus the display name.
#[derive(Clone, Copy, Debug)]
pub struct SimTenantSpec {
    /// CFS weight (≥ 1).
    pub weight: u64,
    /// Token-bucket refill rate, submissions/s (≤ 0 = unthrottled).
    pub rate: f64,
    /// Token-bucket burst capacity, whole submissions (≥ 1).
    pub burst: f64,
    /// Queue-depth cap for `Interactive`; classes scale it down.
    pub depth: usize,
}

/// One submission in a fair-share trace for [`sim_fair_order`].
/// Traces must be sorted by `at_ns`; ties keep slice order, which is
/// the submission order the runtime's front end sees.
#[derive(Clone, Copy, Debug)]
pub struct SimFairArrival {
    pub tenant: usize,
    pub class: crate::sched::LatencyClass,
    /// Declared execution cost charged at completion (min 1 ns).
    pub cost_ns: u64,
    /// Submission time on the serving clock.
    pub at_ns: u64,
}

/// Outcome of a simulated fair-share serve ([`sim_fair_order`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SimFairOutcome {
    /// Indices of the arrivals in release order.
    pub order: Vec<usize>,
    /// Submission → release wait of each release, parallel to `order`.
    pub wait_ns: Vec<u64>,
    /// Indices shed at submit (throttled Background or queue-full).
    pub shed: Vec<usize>,
}

/// The simulator's *independent* model of the fair-share admission
/// front end (`sched::fair`) under the deterministic serving
/// convention pinned by `tests/fairness_conformance.rs`:
///
/// - **Submit phase** — arrivals in `at_ns` order: advance the clock
///   to `at_ns`, admit/queue/shed by the fair rules (class-scaled
///   depth cap first, then the token bucket; a throttled `Background`
///   arrival sheds, anything else queues unpaid), then release at
///   most one entry into the single inflight slot (min-vruntime pick,
///   ties → lower tenant index).
/// - **Drain phase** — serial-service loop: completing the inflight
///   entry charges `cost_ns * 1024 / weight` to its tenant's
///   vruntime and advances the clock by `cost_ns`; when everything
///   queued is throttled, the clock skips to the next token refill
///   (`max(eta, 1)`); each step then releases the next pick.
///
/// Admission arithmetic is GCRA (integer theoretical-arrival-time
/// bucket: `period = round(1e9/rate)` ns, 0 = unthrottled; burst
/// tolerance `(burst-1)·period`) and vruntime is saturating `u128`
/// with a monotone activation floor (new activations clamp up to the
/// smallest active vruntime, advanced at every charge).
///
/// This is a deliberate re-implementation (own bucket and pick code,
/// O(n²) scans, nothing shared with `FairQueue`) so the conformance
/// harness can differentially test the runtime and model against it.
pub fn sim_fair_order(specs: &[SimTenantSpec], arrivals: &[SimFairArrival]) -> SimFairOutcome {
    const UNIT: u128 = 1024; // sched::fair::WEIGHT_UNIT, restated on purpose
    struct Tn {
        /// GCRA: ns per token (0 = unthrottled), burst tolerance, and
        /// the theoretical arrival time of the next conforming take.
        period_ns: u64,
        tau_ns: u64,
        tat_ns: u64,
        weight: u128,
        depth: usize,
        vrt: u128,
        /// (arrival index, class rank, submit_ns, prepaid), ordered
        /// by (rank, submission).
        q: Vec<(usize, u8, u64, bool)>,
    }
    impl Tn {
        fn has_token(&self, now: u64) -> bool {
            self.period_ns == 0 || now.saturating_add(self.tau_ns) >= self.tat_ns
        }
        fn take(&mut self, now: u64) -> bool {
            if self.period_ns == 0 {
                return true;
            }
            if now.saturating_add(self.tau_ns) < self.tat_ns {
                return false;
            }
            self.tat_ns = now.max(self.tat_ns).saturating_add(self.period_ns);
            true
        }
        fn eta(&self, now: u64) -> u64 {
            if self.has_token(now) {
                0
            } else {
                (self.tat_ns - self.tau_ns) - now
            }
        }
    }
    /// Min-vruntime pick over eligible tenants (head prepaid or
    /// payable now); ties break toward the lower tenant index.
    fn pick(tn: &mut [Tn], now: u64) -> Option<(usize, usize, u64)> {
        let mut best: Option<(usize, u128)> = None;
        for (i, t) in tn.iter().enumerate() {
            let Some(&(_, _, _, prepaid)) = t.q.first() else { continue };
            if !prepaid && !t.has_token(now) {
                continue;
            }
            if best.is_none_or(|(_, v)| t.vrt < v) {
                best = Some((i, t.vrt));
            }
        }
        let (ti, _) = best?;
        let (idx, _, at, prepaid) = tn[ti].q.remove(0);
        if !prepaid {
            tn[ti].take(now);
        }
        Some((ti, idx, at))
    }

    let mut tn: Vec<Tn> = specs
        .iter()
        .map(|s| {
            let period_ns = if !s.rate.is_finite() || s.rate <= 0.0 || s.rate >= 1e9 {
                0
            } else {
                (1e9 / s.rate).round().max(1.0) as u64
            };
            let burst = if s.burst.is_finite() && s.burst >= 1.0 { s.burst.round() as u64 } else { 1 };
            Tn {
                period_ns,
                tau_ns: (burst - 1).saturating_mul(period_ns),
                tat_ns: 0,
                weight: s.weight.max(1) as u128,
                depth: s.depth,
                vrt: 0,
                q: Vec::new(),
            }
        })
        .collect();
    let mut out = SimFairOutcome::default();
    let mut min_vrt: u128 = 0;
    let mut clock: u64 = 0;
    // The single inflight slot: (arrival index, tenant, charge cost).
    let mut inflight: Option<(usize, usize, u64)> = None;

    // Submit phase.
    for (i, a) in arrivals.iter().enumerate() {
        clock = clock.max(a.at_ns);
        let rank = a.class.rank();
        let t = &mut tn[a.tenant];
        if t.q.len() >= (t.depth >> rank).max(1) {
            out.shed.push(i);
        } else {
            let prepaid = t.take(clock);
            if !prepaid && a.class == crate::sched::LatencyClass::Background {
                out.shed.push(i);
            } else {
                if t.q.is_empty() {
                    // Activation clamp up to the monotone floor.
                    t.vrt = t.vrt.max(min_vrt);
                }
                let pos = t.q.iter().position(|e| e.1 > rank).unwrap_or(t.q.len());
                t.q.insert(pos, (i, rank, clock, prepaid));
            }
        }
        if inflight.is_none() {
            if let Some((ti, idx, at)) = pick(&mut tn, clock) {
                out.order.push(idx);
                out.wait_ns.push(clock.saturating_sub(at));
                inflight = Some((idx, ti, arrivals[idx].cost_ns.max(1)));
            }
        }
    }

    // Drain phase (serial-service model).
    loop {
        if let Some((_, ti, cost)) = inflight.take() {
            tn[ti].vrt = tn[ti].vrt.saturating_add(cost as u128 * UNIT / tn[ti].weight);
            let active = tn.iter().filter(|t| !t.q.is_empty()).map(|t| t.vrt).min().unwrap_or(tn[ti].vrt);
            min_vrt = min_vrt.max(active);
            clock = clock.saturating_add(cost);
        } else if tn.iter().any(|t| !t.q.is_empty()) {
            // Everything queued is throttled: skip to the next token.
            let eta = tn
                .iter()
                .filter_map(|t| t.q.first().map(|e| if e.3 { 0 } else { t.eta(clock) }))
                .min()
                .unwrap_or(1)
                .max(1);
            clock = clock.saturating_add(eta);
        } else {
            break;
        }
        if let Some((ti, idx, at)) = pick(&mut tn, clock) {
            out.order.push(idx);
            out.wait_ns.push(clock.saturating_sub(at));
            inflight = Some((idx, ti, arrivals[idx].cost_ns.max(1)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::{simulate_loop, LoopSpec};
    use crate::sim::machine::MachineSpec;

    fn run(policy: &Policy, weights: Vec<f64>, p: usize) -> crate::sim::engine::SimResult {
        let spec = MachineSpec::default();
        let ls = LoopSpec::new(weights, 0.0);
        let mut pol = make_sim_policy(policy, &ls.weights, p);
        simulate_loop(&spec, p, &ls, 42, pol.as_mut())
    }

    fn all_policies() -> Vec<Policy> {
        vec![
            Policy::Static,
            Policy::Dynamic { chunk: 2 },
            Policy::Guided { chunk: 1 },
            Policy::Taskloop { num_tasks: 0 },
            Policy::Factoring { alpha: 2.0 },
            Policy::Binlpt { max_chunks: 16 },
            Policy::Stealing { chunk: 2 },
            Policy::Ich(IchParams::default()),
            Policy::Awf,
            Policy::Hss,
        ]
    }

    #[test]
    fn every_policy_simulates_all_iterations() {
        let weights: Vec<f64> = (0..500).map(|i| 1.0 + (i % 13) as f64).collect();
        for pol in all_policies() {
            for &p in &[1usize, 4, 28] {
                let r = run(&pol, weights.clone(), p);
                assert_eq!(
                    r.iters_per_thread.iter().sum::<u64>(),
                    500,
                    "policy {} p={p}",
                    pol.name()
                );
                assert!(r.time > 0.0);
            }
        }
    }

    #[test]
    fn uniform_work_speeds_up_with_threads() {
        // 2000 unit-100 iterations: any sane policy gets near-linear
        // speedup from 1 → 8 threads on a compute-bound loop.
        let weights = vec![100.0; 2000];
        for pol in [Policy::Ich(IchParams::default()), Policy::Dynamic { chunk: 2 }, Policy::Guided { chunk: 1 }] {
            let t1 = run(&pol, weights.clone(), 1).time;
            let t8 = run(&pol, weights.clone(), 8).time;
            let sp = t1 / t8;
            assert!(sp > 5.0, "policy {} speedup(8) = {sp:.2}", pol.name());
        }
    }

    #[test]
    fn ich_steals_on_imbalance() {
        // All the work in the first block: iCh must steal.
        let mut weights = vec![1.0; 1000];
        for w in weights.iter_mut().take(250) {
            *w = 500.0;
        }
        let r = run(&Policy::Ich(IchParams::default()), weights, 4);
        assert!(r.steals_ok > 0, "expected steals, got {:?}", r);
    }

    #[test]
    fn steal_locality_is_tracked_on_the_two_socket_model() {
        // 28 threads over 2×14 sockets with the work in socket 0's
        // blocks: the two-tier victim selection must record locality,
        // and local steals can never exceed total steals.
        let mut weights = vec![1.0; 2800];
        for w in weights.iter_mut().take(200) {
            *w = 500.0;
        }
        let r = run(&Policy::Ich(IchParams::default()), weights, 28);
        assert!(r.steals_ok > 0, "expected steals, got {r:?}");
        assert!(r.steals_local <= r.steals_ok);
        assert!(r.steals_local > 0, "socket-0 thieves should hit local victims under the 7/8 bias");
    }

    #[test]
    fn stealing_beats_static_on_imbalance() {
        let mut weights = vec![1.0; 2800];
        for w in weights.iter_mut().take(100) {
            *w = 1000.0;
        }
        let t_static = run(&Policy::Static, weights.clone(), 28).time;
        let t_steal = run(&Policy::Stealing { chunk: 1 }, weights.clone(), 28).time;
        assert!(
            t_steal < t_static * 0.6,
            "stealing {t_steal:.0} should beat static {t_static:.0} by a wide margin"
        );
    }

    #[test]
    fn dynamic_chunk1_pays_overhead_on_tiny_iterations() {
        // Tiny iterations: dynamic,1 drowns in central dispatch
        // overhead vs guided's big chunks (the paper's SpMV pathology).
        let weights = vec![2.0; 50_000];
        let t_dyn = run(&Policy::Dynamic { chunk: 1 }, weights.clone(), 28).time;
        let t_gui = run(&Policy::Guided { chunk: 1 }, weights.clone(), 28).time;
        assert!(t_gui * 2.0 < t_dyn, "guided {t_gui:.0} vs dynamic,1 {t_dyn:.0}");
    }

    #[test]
    fn guided_collapses_on_decreasing_workload() {
        // Exp-decreasing: guided gives the huge first chunks to the
        // heaviest iterations — one thread drags the loop (Fig 4).
        let mut rng = crate::util::rng::Rng::new(9);
        let mut w: Vec<f64> = (0..20_000).map(|_| rng.exponential(1000.0)).collect();
        w.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let t_gui = run(&Policy::Guided { chunk: 1 }, w.clone(), 28).time;
        let t_dyn = run(&Policy::Dynamic { chunk: 3 }, w.clone(), 28).time;
        assert!(t_dyn < t_gui, "dynamic {t_dyn:.0} should beat guided {t_gui:.0} on Exp-Dec");
    }

    #[test]
    fn deterministic() {
        let weights: Vec<f64> = (0..1000).map(|i| 1.0 + (i % 7) as f64).collect();
        let a = run(&Policy::Ich(IchParams::default()), weights.clone(), 14);
        let b = run(&Policy::Ich(IchParams::default()), weights, 14);
        assert_eq!(a.time, b.time);
        assert_eq!(a.steals_ok, b.steals_ok);
    }

    #[test]
    fn dispatch_model_orders_classes_and_deadlines() {
        use crate::sched::LatencyClass as C;
        let t = |class, deadline, after| SimArrival { class, deadline, origin: None, after };
        // One batch: Background first-in, then Batch with deadlines,
        // then Interactive.
        let order = sim_dispatch_order(
            &[
                t(C::Background, None, 0),
                t(C::Batch, Some(20), 0),
                t(C::Batch, Some(10), 0),
                t(C::Interactive, None, 0),
            ],
            4,
        );
        assert_eq!(order, vec![3, 2, 1, 0]);
    }

    #[test]
    fn dispatch_model_promotes_bypassed_background() {
        use crate::sched::LatencyClass as C;
        // A Background entry with a stream of Interactive arrivals
        // landing behind it (one new arrival per dispatch): with
        // promote_k = 2 it must dispatch after exactly 2 bypasses.
        let mut arrivals = vec![SimArrival { class: C::Background, deadline: None, origin: None, after: 0 }];
        for i in 0..5usize {
            arrivals.push(SimArrival { class: C::Interactive, deadline: None, origin: None, after: i });
        }
        let order = sim_dispatch_order(&arrivals, 2);
        let bg_pos = order.iter().position(|&i| i == 0).unwrap();
        assert_eq!(bg_pos, 2, "background dispatches after exactly k = 2 bypasses: {order:?}");
    }

    #[test]
    fn dispatch_model_single_class_is_fifo() {
        use crate::sched::LatencyClass as C;
        let arrivals: Vec<SimArrival> =
            (0..7).map(|i| SimArrival { class: C::Batch, deadline: None, origin: None, after: i / 3 }).collect();
        assert_eq!(sim_dispatch_order(&arrivals, 4), (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn dispatch_model_distance_weight_reorders_within_class() {
        use crate::sched::LatencyClass as C;
        let excess = |w: usize, o: usize| if w == o { 0 } else { 11u64 };
        let arrivals = [
            SimArrival { class: C::Batch, deadline: Some(10), origin: Some(1), after: 0 },
            SimArrival { class: C::Batch, deadline: Some(15), origin: Some(0), after: 0 },
        ];
        // A node-0 claimant inflates the far entry past the near one.
        assert_eq!(sim_dispatch_order_from(&arrivals, 4, Some(0), &excess), vec![1, 0]);
        // A node-1 claimant (and the neutral model) keep plain EDF.
        assert_eq!(sim_dispatch_order_from(&arrivals, 4, Some(1), &excess), vec![0, 1]);
        assert_eq!(sim_dispatch_order(&arrivals, 4), vec![0, 1]);
    }

    #[test]
    fn ranked_victim_selection_runs_on_the_two_socket_model() {
        // Drive the WsSim with an explicit Ranked victim policy (the
        // process default is left alone): socket-0-heavy work on the
        // 2×14 model must complete exactly, record locality, and be
        // deterministic — the ranked selector consumes the machine's
        // distance matrix just like the engines consume the detected
        // topology's.
        let spec = MachineSpec::default();
        let mut weights = vec![1.0; 2800];
        for w in weights.iter_mut().take(200) {
            *w = 500.0;
        }
        let ls = LoopSpec::new(weights, 0.0);
        let run_once = || {
            let mut pol = WsSim::with_victim(
                ls.weights.len(),
                28,
                WsMode::Adaptive(IchParams::default()),
                VictimPolicy::Ranked,
            );
            simulate_loop(&spec, 28, &ls, 42, &mut pol)
        };
        let r = run_once();
        assert_eq!(r.iters_per_thread.iter().sum::<u64>(), 2800);
        assert!(r.steals_ok > 0, "imbalanced ranked run must steal: {r:?}");
        assert!(r.steals_local <= r.steals_ok);
        assert!(r.steals_local > 0, "ranked bias must find same-socket victims");
        let r2 = run_once();
        assert_eq!(r.time, r2.time, "ranked sim must stay deterministic");
        assert_eq!(r.steals_ok, r2.steals_ok);
    }

    #[test]
    fn assist_sim_conserves_iterations_for_every_policy() {
        // 4 members + 2 joiners arriving mid-loop: every policy must
        // still execute each iteration exactly once (conservation), and
        // the assistable ones must actually let the joiners work.
        let weights: Vec<f64> = (0..600).map(|i| 1.0 + (i % 17) as f64 * 10.0).collect();
        let spec = MachineSpec::default();
        let ls = LoopSpec::new(weights.clone(), 0.0);
        for pol in all_policies() {
            let arrive = [50.0, 200.0];
            let mut sched = make_assist_sim_policy(&pol, &ls.weights, 4, &arrive);
            let r = simulate_loop(&spec, 4 + arrive.len(), &ls, 11, sched.as_mut());
            assert_eq!(r.iters_per_thread.iter().sum::<u64>(), 600, "policy {}", pol.name());
        }
    }

    #[test]
    fn assist_sim_joiners_share_assistable_work() {
        // Straggler-heavy central-queue loop: joiners arriving early
        // must pick up a share of the iterations (nonzero joiner tids).
        let weights = vec![100.0; 2000];
        let spec = MachineSpec::default();
        let ls = LoopSpec::new(weights, 0.0);
        let arrive = [1.0, 1.0];
        let mut sched = make_assist_sim_policy(&Policy::Dynamic { chunk: 4 }, &ls.weights, 2, &arrive);
        let r = simulate_loop(&spec, 4, &ls, 3, sched.as_mut());
        assert_eq!(r.iters_per_thread.iter().sum::<u64>(), 2000);
        let joiner_iters: u64 = r.iters_per_thread[2..].iter().sum();
        assert!(joiner_iters > 0, "early joiners must execute iterations: {r:?}");
    }

    #[test]
    fn assist_sim_late_joiner_backs_out_without_joining() {
        // Joiner arrival far beyond the loop's makespan: it must lose
        // the finish race, execute nothing, and never count as an
        // assist — the sim's mirror of the gate's closed CAS.
        let weights = vec![10.0; 100];
        let spec = MachineSpec::default();
        let ls = LoopSpec::new(weights, 0.0);
        let inner = make_sim_policy(&Policy::Dynamic { chunk: 4 }, &ls.weights, 2);
        let mut sched = AssistSim::new(inner, 2, vec![1e18]);
        let r = simulate_loop(&spec, 3, &ls, 5, &mut sched);
        assert_eq!(r.iters_per_thread.iter().sum::<u64>(), 100);
        assert_eq!(r.iters_per_thread[2], 0, "late joiner must not execute work");
        assert_eq!(sched.assists, 0, "a backed-out joiner never registers");
    }

    #[test]
    fn assist_sim_with_no_joiners_matches_base_policy() {
        // Zero joiners: the wrapper must be a pass-through — identical
        // trajectory (time, steals, per-thread iterations) to the bare
        // policy. This is the sim side of the off-path differential.
        let weights: Vec<f64> = (0..1400).map(|i| 1.0 + (i % 5) as f64 * 40.0).collect();
        let spec = MachineSpec::default();
        let ls = LoopSpec::new(weights, 0.0);
        for pol in all_policies() {
            let mut base = make_sim_policy(&pol, &ls.weights, 4);
            let a = simulate_loop(&spec, 4, &ls, 21, base.as_mut());
            let mut wrapped = make_assist_sim_policy(&pol, &ls.weights, 4, &[]);
            let b = simulate_loop(&spec, 4, &ls, 21, wrapped.as_mut());
            assert_eq!(a.time, b.time, "policy {}", pol.name());
            assert_eq!(a.steals_ok, b.steals_ok, "policy {}", pol.name());
            assert_eq!(a.iters_per_thread, b.iters_per_thread, "policy {}", pol.name());
        }
    }

    #[test]
    fn ws_mu_divisor_tracks_joined_threads() {
        // PR 6 follow-up, pinned against the checker's `mu_merge`
        // model: members have completed 4 and 2 iterations when the
        // assist joiner enters and contributes 6. Pre-join μ divides
        // by the 2 members (μ = 3); post-join by 3 participants
        // (μ = (4+2+6)/3 = 4) — never by the padded slot count, which
        // would deflate μ with never-arrived joiners' zero progress.
        let mut ws = WsSim::adaptive(12, 2, IchParams::default()).padded(3);
        assert_eq!(ws.active, 2, "padding must not widen the divisor");
        ws.states[0].k = 4.0;
        ws.states[1].k = 2.0;
        assert!((ws.mu() - 3.0).abs() < 1e-12, "pre-join μ over members only, got {}", ws.mu());
        ws.notify_join(2);
        ws.states[2].k = 6.0;
        assert!((ws.mu() - 4.0).abs() < 1e-12, "post-join μ counts the joiner, got {}", ws.mu());
    }

    #[test]
    fn assist_sim_forwards_join_to_inner_policy_exactly_once() {
        use crate::util::rng::Rng;

        struct JoinProbe {
            joins: std::rc::Rc<std::cell::RefCell<Vec<usize>>>,
        }
        impl SimSched for JoinProbe {
            fn acquire(&mut self, _tid: usize, _now: f64, _ctx: &mut SimCtx) -> Acquire {
                Acquire::Done
            }
            fn notify_join(&mut self, tid: usize) {
                self.joins.borrow_mut().push(tid);
            }
        }

        let joins = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let mut sched = AssistSim::new(Box::new(JoinProbe { joins: joins.clone() }), 2, vec![0.0]);
        let spec = MachineSpec::default();
        let mut ctx = SimCtx {
            spec: &spec,
            p: 3,
            n: 10,
            rng: Rng::new(0),
            central_free: 0.0,
            queue_free: vec![0.0; 3],
            executed: 0,
            chunks: 0,
            steals_ok: 0,
            steals_local: 0,
            steals_fail: 0,
        };
        let _ = sched.acquire(0, 0.0, &mut ctx); // member: never a join
        let _ = sched.acquire(2, 1.0, &mut ctx); // joiner enters
        let _ = sched.acquire(2, 2.0, &mut ctx); // re-acquire: no second join
        assert_eq!(*joins.borrow(), vec![2], "joiner tid forwarded to the inner policy once");
        assert_eq!(sched.assists, 1);
    }

    #[test]
    fn ranked_on_equidistant_matrix_matches_uniform_sim() {
        // An all-equidistant matrix has nothing to rank by: the gate
        // must fall back to the exact uniform path, so the whole sim
        // trajectory (RNG stream included) matches Uniform bit-exactly.
        let spec = MachineSpec { distance: vec![vec![10, 10], vec![10, 10]], ..Default::default() };
        let mut weights = vec![1.0; 1400];
        for w in weights.iter_mut().take(100) {
            *w = 300.0;
        }
        let ls = LoopSpec::new(weights, 0.0);
        let run_with = |victim: VictimPolicy| {
            let mut pol =
                WsSim::with_victim(ls.weights.len(), 28, WsMode::Adaptive(IchParams::default()), victim);
            simulate_loop(&spec, 28, &ls, 7, &mut pol)
        };
        let ranked = run_with(VictimPolicy::Ranked);
        let uniform = run_with(VictimPolicy::Uniform);
        assert_eq!(ranked.time, uniform.time, "equidistant Ranked must be byte-identical to Uniform");
        assert_eq!(ranked.steals_ok, uniform.steals_ok);
        assert_eq!(ranked.steals_fail, uniform.steals_fail);
        assert_eq!(ranked.iters_per_thread, uniform.iters_per_thread);
    }
}
