//! Discrete-event engine: executes one parallel loop under a
//! scheduling policy on the virtual machine, in virtual time.
//!
//! Threads alternate between *acquiring* work (consulting the policy,
//! paying modeled scheduling overheads, possibly waiting on serialized
//! resources) and *executing* chunks (cost = Σ iteration weights ×
//! core-speed / memory multipliers). Threads that fail to acquire work
//! park with a backoff deadline but are woken eagerly whenever any
//! chunk completes — modeling the spin-wait of a real runtime, where a
//! state change is observed within a cache-miss, not a backoff tick.
//! The engine is deterministic given the seed.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::machine::MachineSpec;
use crate::util::rng::Rng;

/// One parallel loop to simulate.
#[derive(Clone, Debug)]
pub struct LoopSpec {
    /// Per-iteration work (abstract units; 1 unit = 1 virtual time unit
    /// on a nominal core).
    pub weights: Vec<f64>,
    /// Fraction of execution bound by the memory system (0 = pure
    /// compute, 1 = streaming): drives NUMA + saturation penalties.
    pub mem_intensity: f64,
}

impl LoopSpec {
    pub fn new(weights: Vec<f64>, mem_intensity: f64) -> LoopSpec {
        LoopSpec { weights, mem_intensity }
    }
}

/// What a thread does when it asks the policy for work.
#[derive(Clone, Debug, PartialEq)]
pub enum Acquire {
    /// Execute iterations [lo, hi); `overhead` is the scheduling cost
    /// already including any serialization waits.
    Chunk { lo: usize, hi: usize, overhead: f64 },
    /// No work obtained (failed steal, backoff); ask again at `until`
    /// or when any chunk completes, whichever happens first.
    Busy { until: f64 },
    /// This thread is finished for this loop.
    Done,
}

/// Mutable context the policies share with the engine: serialized
/// resource clocks, RNG, and progress counters.
pub struct SimCtx<'a> {
    pub spec: &'a MachineSpec,
    pub p: usize,
    pub n: usize,
    pub rng: Rng,
    /// Central-queue server: busy until this time.
    pub central_free: f64,
    /// Per-thread queue lock servers (steal serialization).
    pub queue_free: Vec<f64>,
    /// Iterations fully executed so far.
    pub executed: usize,
    // --- counters for validation / metrics ---
    pub chunks: u64,
    pub steals_ok: u64,
    /// Successful steals where thief and victim share a socket
    /// (`steals_local ≤ steals_ok`), mirroring the real runtime's
    /// locality counters.
    pub steals_local: u64,
    pub steals_fail: u64,
}

impl SimCtx<'_> {
    /// Serialize an operation through the central queue starting no
    /// earlier than `now`: the op costs `total` to the caller and holds
    /// the queue for `serial`. Returns the caller's total delay.
    pub fn central_op(&mut self, now: f64, total: f64, serial: f64) -> f64 {
        let start = self.central_free.max(now);
        self.central_free = start + serial;
        (start - now) + total
    }

    /// Serialize on a victim's queue lock; returns total delay.
    pub fn queue_op(&mut self, victim: usize, now: f64, total: f64, serial: f64) -> f64 {
        let start = self.queue_free[victim].max(now);
        self.queue_free[victim] = start + serial;
        (start - now) + total
    }

    /// Socket a pinned thread lives on.
    pub fn socket_of(&self, tid: usize) -> usize {
        self.spec.socket_of(tid)
    }
}

/// A scheduling policy driven by the engine (the sim-side mirror of
/// `sched::Policy`, sharing the math in `sched::policy`).
pub trait SimSched {
    /// Thread `tid` is idle at `now`: decide its next action.
    fn acquire(&mut self, tid: usize, now: f64, ctx: &mut SimCtx) -> Acquire;
    /// Chunk [lo, hi) finished at `now` on `tid`.
    fn on_complete(&mut self, _tid: usize, _lo: usize, _hi: usize, _now: f64, _ctx: &mut SimCtx) {}
    /// An assist joiner `tid` entered the loop (fired once per joiner,
    /// by `AssistSim`). Policies whose estimates divide by the number
    /// of participants widen the divisor here, mirroring the runtime's
    /// `ws::Shared::register_joiner`.
    fn notify_join(&mut self, _tid: usize) {}
}

/// Result of simulating one loop (or a whole loop sequence).
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    /// Virtual makespan.
    pub time: f64,
    pub chunks: u64,
    pub steals_ok: u64,
    /// Same-socket successful steals (≤ `steals_ok`).
    pub steals_local: u64,
    pub steals_fail: u64,
    /// Iterations executed per thread (validation: sums to n).
    pub iters_per_thread: Vec<u64>,
}

impl SimResult {
    /// Accumulate another loop's result (loop sequences / apps).
    pub fn absorb(&mut self, other: &SimResult) {
        self.time += other.time;
        self.chunks += other.chunks;
        self.steals_ok += other.steals_ok;
        self.steals_local += other.steals_local;
        self.steals_fail += other.steals_fail;
        if self.iters_per_thread.len() < other.iters_per_thread.len() {
            self.iters_per_thread.resize(other.iters_per_thread.len(), 0);
        }
        for (a, b) in self.iters_per_thread.iter_mut().zip(&other.iters_per_thread) {
            *a += b;
        }
    }
}

// Ord is required by BinaryHeap but never consulted: the (time, seq)
// key is unique per entry.
#[derive(Debug, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    /// Thread wants work; valid only if `epoch` is current.
    Ready { epoch: u64 },
    Completed { lo: usize, hi: usize },
}

/// Simulate one parallel loop with a fresh policy instance (like a
/// fresh `parallel_for` in libgomp).
pub fn simulate_loop(
    spec: &MachineSpec,
    p: usize,
    loop_spec: &LoopSpec,
    seed: u64,
    policy: &mut dyn SimSched,
) -> SimResult {
    let n = loop_spec.weights.len();
    let mut res = SimResult { iters_per_thread: vec![0; p], ..Default::default() };
    if n == 0 {
        return res;
    }

    // Prefix sums for O(1) range work.
    let mut prefix = Vec::with_capacity(n + 1);
    prefix.push(0.0);
    for &w in &loop_spec.weights {
        prefix.push(prefix.last().unwrap() + w);
    }

    let speeds = spec.core_speeds(p, seed);
    // First-touch data homes: socket 0 owns the iterations in the
    // static blocks of the first `cores_per_socket` threads.
    let socket0_end = if p <= spec.cores_per_socket {
        n
    } else {
        let blocks = crate::sched::policy::static_blocks(n, p);
        blocks.get(spec.cores_per_socket - 1).map_or(n, |b| b.1)
    };
    let threads_on = |s: usize| -> usize { (0..p).filter(|&t| spec.socket_of(t) == s).count() };
    let sat: Vec<f64> =
        (0..spec.sockets).map(|s| spec.saturation_mult(threads_on(s), loop_spec.mem_intensity)).collect();

    let range_cost = |lo: usize, hi: usize, tid: usize| -> f64 {
        let base = prefix[hi] - prefix[lo];
        let sock = spec.socket_of(tid);
        let len = (hi - lo) as f64;
        let local_len = if sock == 0 {
            (hi.min(socket0_end).saturating_sub(lo.min(socket0_end))) as f64
        } else {
            (hi.max(socket0_end) - lo.max(socket0_end)) as f64
        };
        let fr_remote = if len > 0.0 { 1.0 - local_len / len } else { 0.0 };
        let mem_mult = 1.0 + loop_spec.mem_intensity * spec.remote_mem_penalty * fr_remote;
        base / speeds[tid] * sat[sock] * mem_mult
    };

    let mut ctx = SimCtx {
        spec,
        p,
        n,
        rng: Rng::new(seed ^ 0x51D_EC0DE),
        central_free: 0.0,
        queue_free: vec![0.0; p],
        executed: 0,
        chunks: 0,
        steals_ok: 0,
        steals_local: 0,
        steals_fail: 0,
    };

    // Min-heap on (time_bits, seq); times are nonnegative, so the bit
    // pattern of f64 orders identically to the value.
    let mut heap: BinaryHeap<(Reverse<(u64, u64)>, usize, Event)> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut epochs = vec![0u64; p];
    let mut parked = vec![false; p];

    macro_rules! push {
        ($t:expr, $tid:expr, $ev:expr) => {{
            heap.push((Reverse((f64::to_bits($t), seq)), $tid, $ev));
            seq += 1;
        }};
    }

    // Fork: threads wake staggered (master first).
    for tid in 0..p {
        let t = spec.c_fork_base + spec.c_fork_per_thread * tid as f64;
        push!(t, tid, Event::Ready { epoch: 0 });
    }

    let mut makespan = 0.0f64;
    let mut done = vec![false; p];
    let mut done_threads = 0usize;
    while let Some((Reverse((tb, _)), tid, ev)) = heap.pop() {
        let now = f64::from_bits(tb);
        match ev {
            Event::Completed { lo, hi } => {
                ctx.executed += hi - lo;
                res.iters_per_thread[tid] += (hi - lo) as u64;
                makespan = makespan.max(now);
                policy.on_complete(tid, lo, hi, now, &mut ctx);
                // Termination wake: once the last iteration completes,
                // spin-waiting threads observe it within a cache miss,
                // not a backoff tick. (Intermediate completions are
                // deliberately NOT broadcast — that would make every
                // completion O(p) events; the bounded steal backoff
                // models the retry latency instead.)
                if ctx.executed >= n {
                    for (t2, is_parked) in parked.iter_mut().enumerate() {
                        if *is_parked && !done[t2] {
                            *is_parked = false;
                            epochs[t2] += 1;
                            push!(now, t2, Event::Ready { epoch: epochs[t2] });
                        }
                    }
                }
                push!(now, tid, Event::Ready { epoch: epochs[tid] });
            }
            Event::Ready { epoch } => {
                if epoch != epochs[tid] || done[tid] {
                    continue; // stale wake
                }
                // This token is now consumed; the thread is no longer
                // parked (it either runs, re-parks, or retires below).
                parked[tid] = false;
                match policy.acquire(tid, now, &mut ctx) {
                    Acquire::Chunk { lo, hi, overhead } => {
                        debug_assert!(lo < hi && hi <= n);
                        ctx.chunks += 1;
                        let finish = now + overhead + range_cost(lo, hi, tid);
                        push!(finish, tid, Event::Completed { lo, hi });
                    }
                    Acquire::Busy { until } => {
                        parked[tid] = true;
                        epochs[tid] += 1;
                        push!(until.max(now), tid, Event::Ready { epoch: epochs[tid] });
                    }
                    Acquire::Done => {
                        makespan = makespan.max(now);
                        done[tid] = true;
                        done_threads += 1;
                    }
                }
            }
        }
    }
    assert_eq!(done_threads, p, "every thread must retire");
    assert_eq!(ctx.executed, n, "sim must execute every iteration exactly once");

    res.time = makespan;
    res.chunks = ctx.chunks;
    res.steals_ok = ctx.steals_ok;
    res.steals_local = ctx.steals_local;
    res.steals_fail = ctx.steals_fail;
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trivial policy: one chunk covering everything, thread 0 only.
    struct OneShot {
        fired: bool,
        n: usize,
    }
    impl SimSched for OneShot {
        fn acquire(&mut self, tid: usize, _now: f64, _ctx: &mut SimCtx) -> Acquire {
            if tid == 0 && !self.fired {
                self.fired = true;
                Acquire::Chunk { lo: 0, hi: self.n, overhead: 0.0 }
            } else {
                Acquire::Done
            }
        }
    }

    #[test]
    fn single_chunk_makespan_equals_work() {
        let spec = MachineSpec { speed_jitter: 0.0, c_fork_base: 0.0, c_fork_per_thread: 0.0, ..Default::default() };
        let ls = LoopSpec::new(vec![2.0; 50], 0.0);
        let mut pol = OneShot { fired: false, n: 50 };
        let r = simulate_loop(&spec, 1, &ls, 1, &mut pol);
        assert!((r.time - 100.0).abs() < 1e-9, "makespan {}", r.time);
        assert_eq!(r.chunks, 1);
        assert_eq!(r.iters_per_thread, vec![50]);
    }

    #[test]
    fn empty_loop_is_free() {
        let spec = MachineSpec::default();
        let ls = LoopSpec::new(vec![], 0.0);
        let mut pol = OneShot { fired: false, n: 0 };
        let r = simulate_loop(&spec, 4, &ls, 1, &mut pol);
        assert_eq!(r.time, 0.0);
    }

    /// Policy that parks forever until work completes elsewhere —
    /// exercises the eager wake path.
    struct ParkThenDone {
        issued: bool,
    }
    impl SimSched for ParkThenDone {
        fn acquire(&mut self, tid: usize, now: f64, ctx: &mut SimCtx) -> Acquire {
            if tid == 0 {
                if !self.issued {
                    self.issued = true;
                    return Acquire::Chunk { lo: 0, hi: ctx.n, overhead: 0.0 };
                }
                return Acquire::Done;
            }
            if ctx.executed >= ctx.n {
                Acquire::Done
            } else {
                // huge backoff — must be cut short by the eager wake
                Acquire::Busy { until: now + 1e12 }
            }
        }
    }

    #[test]
    fn parked_threads_wake_on_completion() {
        let spec = MachineSpec { c_fork_base: 0.0, c_fork_per_thread: 0.0, speed_jitter: 0.0, ..Default::default() };
        let ls = LoopSpec::new(vec![1.0; 100], 0.0);
        let mut pol = ParkThenDone { issued: false };
        let r = simulate_loop(&spec, 4, &ls, 1, &mut pol);
        // Makespan ≈ 100 work units, NOT the 1e12 backoff.
        assert!(r.time < 200.0, "eager wake failed: makespan {}", r.time);
    }

    #[test]
    fn central_op_serializes() {
        let spec = MachineSpec::default();
        let mut ctx = SimCtx {
            spec: &spec,
            p: 2,
            n: 0,
            rng: Rng::new(0),
            central_free: 0.0,
            queue_free: vec![0.0; 2],
            executed: 0,
            chunks: 0,
            steals_ok: 0,
            steals_local: 0,
            steals_fail: 0,
        };
        let d1 = ctx.central_op(0.0, 8.0, 3.0);
        let d2 = ctx.central_op(0.0, 8.0, 3.0); // queued behind the first
        assert_eq!(d1, 8.0);
        assert_eq!(d2, 11.0); // 3 wait + 8 op
    }

    #[test]
    fn absorb_accumulates() {
        let mut a = SimResult { time: 10.0, chunks: 2, iters_per_thread: vec![5, 5], ..Default::default() };
        let b = SimResult { time: 5.0, chunks: 1, steals_ok: 3, iters_per_thread: vec![1, 2], ..Default::default() };
        a.absorb(&b);
        assert_eq!(a.time, 15.0);
        assert_eq!(a.chunks, 3);
        assert_eq!(a.steals_ok, 3);
        assert_eq!(a.iters_per_thread, vec![6, 7]);
    }
}
