//! Virtual machine model for the discrete-event simulator.
//!
//! The paper's testbed is Bridges-RM: two Intel Xeon E5-2695 v3
//! (Haswell) sockets × 14 cores, threads pinned to cores. This
//! container has one core, so speedup experiments run on this model
//! instead (DESIGN.md §3 records the substitution). Virtual time is
//! measured in *work units*: executing one unit of iteration weight on
//! a nominal-speed core takes 1.0 units; every scheduling overhead is
//! expressed in the same currency.

use crate::util::rng::Rng;

/// SLIT convention: a socket's distance to itself.
pub const DIST_LOCAL: u64 = 10;

/// Default cross-socket distance. 25/10 preserves the 2.5× cross-NUMA
/// steal-cost multiplier the model has always been calibrated with
/// (the pre-matrix `numa_steal_mult`).
pub const DIST_REMOTE: u64 = 25;

/// The default local/remote distance matrix for `sockets` sockets.
pub fn default_distance(sockets: usize) -> Vec<Vec<u64>> {
    (0..sockets)
        .map(|a| (0..sockets).map(|b| if a == b { DIST_LOCAL } else { DIST_REMOTE }).collect())
        .collect()
}

/// Topology + cost-model constants.
#[derive(Clone, Debug)]
pub struct MachineSpec {
    /// NUMA sockets.
    pub sockets: usize,
    /// Cores per socket (paper: 14).
    pub cores_per_socket: usize,
    /// Std-dev of per-core speed jitter (DVFS, shared caches; §3.2 of
    /// the paper motivates adaptivity with exactly this variation).
    pub speed_jitter: f64,
    /// Cost of one dispatch from a *central* queue (atomic RMW on a
    /// contended line + bookkeeping).
    pub c_dispatch_central: f64,
    /// Portion of a central dispatch that serializes (queue "server"
    /// occupancy — models cache-line ping-pong under contention).
    pub c_central_serial: f64,
    /// Owner-side dispatch from a local THE deque (uncontended).
    pub c_dispatch_local: f64,
    /// iCh adaptation pass: read p counters + classify (per p threads).
    pub c_adapt_per_thread: f64,
    /// Fixed part of the iCh adaptation pass.
    pub c_adapt_base: f64,
    /// Failed steal probe (load remote queue indices, miss).
    pub c_steal_fail: f64,
    /// Successful steal (victim lock + range cut + state copy).
    pub c_steal_ok: f64,
    /// Serialized portion of a steal on the victim's lock.
    pub c_steal_serial: f64,
    /// SLIT-style socket-distance matrix (`sockets × sockets`,
    /// diagonal = local). Replaces the old scalar `numa_steal_mult`:
    /// steal costs scale by `distance[a][b] / distance[a][a]`
    /// ([`MachineSpec::steal_mult`], §6.2's cross-NUMA steal penalty,
    /// now per distance tier), and the ranked victim selector ranks
    /// victims by these distances exactly like the real runtime ranks
    /// by the detected topology's matrix.
    pub distance: Vec<Vec<u64>>,
    /// Fork-join cost per parallel loop: fixed + per-thread part.
    pub c_fork_base: f64,
    pub c_fork_per_thread: f64,
    /// OpenMP task creation overhead per task (`taskloop` only).
    pub c_task_create: f64,
    /// Execution penalty factor for touching remote-socket data
    /// (applied to the memory-bound fraction of an iteration).
    pub remote_mem_penalty: f64,
    /// Threads per socket beyond which memory bandwidth saturates.
    pub mem_saturation_threads: f64,
}

impl Default for MachineSpec {
    fn default() -> Self {
        MachineSpec {
            sockets: 2,
            cores_per_socket: 14,
            speed_jitter: 0.04,
            c_dispatch_central: 8.0,
            c_central_serial: 3.0,
            c_dispatch_local: 6.0,
            c_adapt_per_thread: 0.15,
            c_adapt_base: 1.0,
            c_steal_fail: 12.0,
            c_steal_ok: 40.0,
            c_steal_serial: 10.0,
            distance: default_distance(2),
            c_fork_base: 60.0,
            c_fork_per_thread: 6.0,
            c_task_create: 30.0,
            remote_mem_penalty: 0.7,
            mem_saturation_threads: 8.0,
        }
    }
}

impl MachineSpec {
    /// The paper's Haswell testbed (the default).
    pub fn bridges_haswell() -> MachineSpec {
        MachineSpec::default()
    }

    pub fn total_cores(&self) -> usize {
        self.sockets * self.cores_per_socket
    }

    /// Socket of a pinned thread (threads fill socket 0 first, as with
    /// OMP_PLACES=cores on the testbed). Oversubscribed tids wrap
    /// modulo the core count, mirroring the runtime: a run wider than
    /// the machine is served by the scoped-spawn fallback (a
    /// persistent pool is never oversubscribed *and* pinned —
    /// `Runtime::with_pinning` gates pinning on a spare core per
    /// worker), whose pinned teams place tid `t` on core
    /// `t % ncpus` (`pool::pin_to_cpu` wraps internally) — so extra
    /// threads cycle across sockets. The seed clamped them all onto
    /// the *last* socket (`.min(sockets-1)`), piling every surplus
    /// thread on socket 1 where no runtime path does.
    pub fn socket_of(&self, tid: usize) -> usize {
        (tid % self.total_cores().max(1)) / self.cores_per_socket.max(1)
    }

    /// SLIT distance from socket `a` to socket `b`. Total: sockets
    /// beyond the matrix (defensive) fall back to local/remote
    /// defaults.
    pub fn node_distance(&self, a: usize, b: usize) -> u64 {
        self.distance
            .get(a)
            .and_then(|row| row.get(b))
            .copied()
            .unwrap_or(if a == b { DIST_LOCAL } else { DIST_REMOTE })
    }

    /// Steal-cost multiplier between sockets: the distance ratio over
    /// the thief's local distance (1.0 on-socket; 2.5 cross-socket
    /// under the default matrix — the old `numa_steal_mult`).
    pub fn steal_mult(&self, thief: usize, victim: usize) -> f64 {
        self.node_distance(thief, victim) as f64 / self.node_distance(thief, thief).max(1) as f64
    }

    /// Does the distance matrix carry no information (one socket, or
    /// every entry equal)? The ranked victim selection gates off here,
    /// mirroring `Topology::is_equidistant`.
    pub fn is_equidistant(&self) -> bool {
        if self.sockets <= 1 {
            return true;
        }
        let first = self.node_distance(0, 0);
        (0..self.sockets).all(|a| (0..self.sockets).all(|b| self.node_distance(a, b) == first))
    }

    /// Per-core speed factors for p threads (deterministic in `seed`).
    pub fn core_speeds(&self, p: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed ^ 0xC0DE_5EED);
        (0..p).map(|_| rng.normal(1.0, self.speed_jitter).clamp(0.7, 1.3)).collect()
    }

    /// Memory-bandwidth saturation multiplier for a socket running
    /// `k` threads of an application with memory intensity `m` ∈ [0,1]:
    /// execution slows once the socket's memory system is oversubscribed.
    pub fn saturation_mult(&self, threads_on_socket: usize, mem_intensity: f64) -> f64 {
        let k = threads_on_socket as f64;
        let sat = self.mem_saturation_threads;
        1.0 + mem_intensity * ((k - sat).max(0.0) / sat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_testbed() {
        let m = MachineSpec::default();
        assert_eq!(m.total_cores(), 28);
        assert_eq!(m.socket_of(0), 0);
        assert_eq!(m.socket_of(13), 0);
        assert_eq!(m.socket_of(14), 1);
        assert_eq!(m.socket_of(27), 1);
    }

    #[test]
    fn oversubscribed_tids_wrap_round_robin() {
        // Regression (this PR): the seed clamped tid ≥ 28 onto the
        // last socket, but the runtime path an oversubscribed run
        // actually takes (the scoped-spawn fallback, whose pinned
        // teams wrap via `pin_to_cpu`'s `% num_cpus`) cycles extra
        // threads across cores — the sim must wrap the same way.
        let m = MachineSpec::default();
        assert_eq!(m.socket_of(28), 0, "tid 28 wraps onto socket 0");
        assert_eq!(m.socket_of(41), 0);
        assert_eq!(m.socket_of(42), 1);
        assert_eq!(m.socket_of(56), 0);
        // The per-socket thread census is then balanced, not piled on
        // the last socket.
        let p = 56;
        let on_socket_1 = (0..p).filter(|&t| m.socket_of(t) == 1).count();
        assert_eq!(on_socket_1, 28, "2× oversubscription splits evenly across sockets");
    }

    #[test]
    fn distance_matrix_preserves_calibrated_steal_mult() {
        let m = MachineSpec::default();
        assert_eq!(m.node_distance(0, 0), DIST_LOCAL);
        assert_eq!(m.node_distance(0, 1), DIST_REMOTE);
        assert!((m.steal_mult(0, 0) - 1.0).abs() < 1e-12);
        assert!((m.steal_mult(0, 1) - 2.5).abs() < 1e-12, "default matrix keeps the 2.5 cross-socket multiplier");
        assert!(!m.is_equidistant());
        // Out-of-matrix sockets degrade to the defaults, never panic.
        assert_eq!(m.node_distance(7, 7), DIST_LOCAL);
        assert_eq!(m.node_distance(7, 8), DIST_REMOTE);
        // Equidistant and single-socket matrices carry no rank signal.
        let flat = MachineSpec { distance: vec![vec![10, 10], vec![10, 10]], ..Default::default() };
        assert!(flat.is_equidistant());
        let single = MachineSpec { sockets: 1, distance: default_distance(1), ..Default::default() };
        assert!(single.is_equidistant());
    }

    #[test]
    fn speeds_deterministic_and_bounded() {
        let m = MachineSpec::default();
        let a = m.core_speeds(28, 7);
        let b = m.core_speeds(28, 7);
        assert_eq!(a, b);
        assert!(a.iter().all(|&s| (0.7..=1.3).contains(&s)));
        let c = m.core_speeds(28, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn saturation_kicks_in_past_threshold() {
        let m = MachineSpec::default();
        assert_eq!(m.saturation_mult(4, 1.0), 1.0);
        assert_eq!(m.saturation_mult(8, 1.0), 1.0);
        assert!(m.saturation_mult(14, 1.0) > 1.5);
        // compute-bound apps don't saturate
        assert_eq!(m.saturation_mult(14, 0.0), 1.0);
    }
}
