//! Virtual machine model for the discrete-event simulator.
//!
//! The paper's testbed is Bridges-RM: two Intel Xeon E5-2695 v3
//! (Haswell) sockets × 14 cores, threads pinned to cores. This
//! container has one core, so speedup experiments run on this model
//! instead (DESIGN.md §3 records the substitution). Virtual time is
//! measured in *work units*: executing one unit of iteration weight on
//! a nominal-speed core takes 1.0 units; every scheduling overhead is
//! expressed in the same currency.

use crate::util::rng::Rng;

/// Topology + cost-model constants.
#[derive(Clone, Debug)]
pub struct MachineSpec {
    /// NUMA sockets.
    pub sockets: usize,
    /// Cores per socket (paper: 14).
    pub cores_per_socket: usize,
    /// Std-dev of per-core speed jitter (DVFS, shared caches; §3.2 of
    /// the paper motivates adaptivity with exactly this variation).
    pub speed_jitter: f64,
    /// Cost of one dispatch from a *central* queue (atomic RMW on a
    /// contended line + bookkeeping).
    pub c_dispatch_central: f64,
    /// Portion of a central dispatch that serializes (queue "server"
    /// occupancy — models cache-line ping-pong under contention).
    pub c_central_serial: f64,
    /// Owner-side dispatch from a local THE deque (uncontended).
    pub c_dispatch_local: f64,
    /// iCh adaptation pass: read p counters + classify (per p threads).
    pub c_adapt_per_thread: f64,
    /// Fixed part of the iCh adaptation pass.
    pub c_adapt_base: f64,
    /// Failed steal probe (load remote queue indices, miss).
    pub c_steal_fail: f64,
    /// Successful steal (victim lock + range cut + state copy).
    pub c_steal_ok: f64,
    /// Serialized portion of a steal on the victim's lock.
    pub c_steal_serial: f64,
    /// Multiplier on steal costs when thief and victim are on
    /// different sockets (§6.2 notes the cross-NUMA steal penalty).
    pub numa_steal_mult: f64,
    /// Fork-join cost per parallel loop: fixed + per-thread part.
    pub c_fork_base: f64,
    pub c_fork_per_thread: f64,
    /// OpenMP task creation overhead per task (`taskloop` only).
    pub c_task_create: f64,
    /// Execution penalty factor for touching remote-socket data
    /// (applied to the memory-bound fraction of an iteration).
    pub remote_mem_penalty: f64,
    /// Threads per socket beyond which memory bandwidth saturates.
    pub mem_saturation_threads: f64,
}

impl Default for MachineSpec {
    fn default() -> Self {
        MachineSpec {
            sockets: 2,
            cores_per_socket: 14,
            speed_jitter: 0.04,
            c_dispatch_central: 8.0,
            c_central_serial: 3.0,
            c_dispatch_local: 6.0,
            c_adapt_per_thread: 0.15,
            c_adapt_base: 1.0,
            c_steal_fail: 12.0,
            c_steal_ok: 40.0,
            c_steal_serial: 10.0,
            numa_steal_mult: 2.5,
            c_fork_base: 60.0,
            c_fork_per_thread: 6.0,
            c_task_create: 30.0,
            remote_mem_penalty: 0.7,
            mem_saturation_threads: 8.0,
        }
    }
}

impl MachineSpec {
    /// The paper's Haswell testbed (the default).
    pub fn bridges_haswell() -> MachineSpec {
        MachineSpec::default()
    }

    pub fn total_cores(&self) -> usize {
        self.sockets * self.cores_per_socket
    }

    /// Socket of a pinned thread (threads fill socket 0 first, as with
    /// OMP_PLACES=cores on the testbed).
    pub fn socket_of(&self, tid: usize) -> usize {
        (tid / self.cores_per_socket).min(self.sockets - 1)
    }

    /// Per-core speed factors for p threads (deterministic in `seed`).
    pub fn core_speeds(&self, p: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed ^ 0xC0DE_5EED);
        (0..p).map(|_| rng.normal(1.0, self.speed_jitter).clamp(0.7, 1.3)).collect()
    }

    /// Memory-bandwidth saturation multiplier for a socket running
    /// `k` threads of an application with memory intensity `m` ∈ [0,1]:
    /// execution slows once the socket's memory system is oversubscribed.
    pub fn saturation_mult(&self, threads_on_socket: usize, mem_intensity: f64) -> f64 {
        let k = threads_on_socket as f64;
        let sat = self.mem_saturation_threads;
        1.0 + mem_intensity * ((k - sat).max(0.0) / sat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_testbed() {
        let m = MachineSpec::default();
        assert_eq!(m.total_cores(), 28);
        assert_eq!(m.socket_of(0), 0);
        assert_eq!(m.socket_of(13), 0);
        assert_eq!(m.socket_of(14), 1);
        assert_eq!(m.socket_of(27), 1);
    }

    #[test]
    fn speeds_deterministic_and_bounded() {
        let m = MachineSpec::default();
        let a = m.core_speeds(28, 7);
        let b = m.core_speeds(28, 7);
        assert_eq!(a, b);
        assert!(a.iter().all(|&s| (0.7..=1.3).contains(&s)));
        let c = m.core_speeds(28, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn saturation_kicks_in_past_threshold() {
        let m = MachineSpec::default();
        assert_eq!(m.saturation_mult(4, 1.0), 1.0);
        assert_eq!(m.saturation_mult(8, 1.0), 1.0);
        assert!(m.saturation_mult(14, 1.0) > 1.5);
        // compute-bound apps don't saturate
        assert_eq!(m.saturation_mult(14, 0.0), 1.0);
    }
}
