//! `ich` — CLI launcher for the iCh loop-scheduling runtime and the
//! paper-reproduction harness.
//!
//! Subcommands:
//!   run      --app <name> --sched <policy> --threads <p> [--real]
//!            run one application on the simulated testbed (default)
//!            or for real on this machine's cores (--real)
//!   figure   <fig1|fig3b|fig4|fig5a|fig5b|fig6a|fig6b|fig7>
//!   table    <table1|table2>
//!   summary  §6.1 "insight" table (iCh rank + gap per app)
//!   ablation iCh design-choice ablations
//!   sweep    --app <name>: every family × Table-2 params × threads
//!   regret   --episodes <e> --seed <s> --out <path>: Policy::Auto
//!            regret harness — repeated episodes per (app, machine),
//!            post-exploration mean vs the best fixed engine, written
//!            to BENCH_auto.json
//!   overlap  --threads <p> --jobs <k> --n <iters>: serve k independent
//!            loops sequentially vs overlapped (async epochs) on the
//!            persistent pool and report both wall times
//!   serve    --tenants <k|spec,...> --rate <r> --weight <w0,w1,...>
//!            [--virtual]: sustained multi-tenant serving through the
//!            fair-share admission front end — open-loop Poisson
//!            arrivals over mixed tenants/classes, per-tenant p50/p99
//!            queue waits, shed counts, and Jain's fairness index,
//!            recorded to BENCH_serving.json
//!   analyze  whole-crate static concurrency-contract analyzer (tier-1
//!            CI gate): lock-order cycles, blocking calls reachable
//!            from claim loops, the structural claim-loop contract,
//!            MEMORY_MODEL edge-ID drift, and the atomics/unsafe
//!            comment lint (strict over src/, SAFETY-only over tests/)
//!   lint-atomics  scan src/ for atomic ops lacking `// order:` comments
//!            and `unsafe` lacking `// SAFETY:` comments (subsumed by
//!            `analyze`; kept for targeted --dir scans)
//!   list     apps, policies, figures
//!   version

use ich::apps;
use ich::coordinator::{Coordinator, LoopJob};
use ich::harness;
use ich::sched::{parallel_for, table2_grid, ExecMode, ForOpts, LatencyClass, Policy, VictimPolicy, PAPER_FAMILIES};
use ich::sim::{simulate_app, MachineSpec};
use ich::util::cli::Args;
use ich::util::table::{f2, Table};

fn main() {
    let args = Args::from_env(&["real", "verbose", "virtual"]);
    // `--steal uniform|topo|ranked` sets the process-wide steal-victim
    // default (every `ForOpts::default()` in apps/harness picks it
    // up); `ICH_STEAL` is the env equivalent. `ranked` needs a
    // topology with distance information (sysfs SLIT or the extended
    // `ICH_TOPOLOGY` syntax, e.g. `2x14@10,21;21,10`) — without one it
    // degrades to the exact uniform path.
    if let Some(s) = args.get("steal") {
        match VictimPolicy::parse(s) {
            Some(v) => {
                let _ = VictimPolicy::set_process_default(v);
            }
            None => {
                eprintln!("unknown steal policy '{s}' (expected: uniform | topo | ranked)");
                std::process::exit(2);
            }
        }
    }
    // `--assist on|off` sets the process-wide work-assisting default
    // (`ICH_ASSIST` is the env equivalent): idle pool workers join
    // in-flight epochs and blocking submitters self-assist their own
    // epoch instead of spinning. Off (the default) keeps the engines
    // byte-identical to the assist-free runtime.
    if let Some(s) = args.get("assist") {
        match ich::sched::assist::parse(s) {
            Some(on) => {
                let _ = ich::sched::assist::set_process_default(on);
            }
            None => {
                eprintln!("unknown assist setting '{s}' (expected: on | off)");
                std::process::exit(2);
            }
        }
    }
    // `--class interactive|batch|background` sets the process-wide
    // dispatch class for pool submissions (`ICH_CLASS` is the env
    // equivalent); `ich overlap` also honors it per run.
    if let Some(s) = args.get("class") {
        match LatencyClass::parse(s) {
            Some(c) => {
                let _ = LatencyClass::set_process_default(c);
            }
            None => {
                eprintln!("unknown latency class '{s}' (expected: interactive | batch | background)");
                std::process::exit(2);
            }
        }
    }
    // `--policy <spec>` sets the process-wide scheduling-policy
    // default (`ICH_POLICY` is the env equivalent). `--policy auto`
    // turns on the online per-loop-site selector; `ICH_AUTO_SEED` /
    // `ICH_AUTO_EXPLORE` tune its exploration hash and floor.
    if let Some(s) = args.get("policy") {
        match Policy::parse(s) {
            Some(p) => {
                let _ = Policy::set_process_default(p);
            }
            None => {
                eprintln!("unknown policy '{s}' (try: auto | ich,0.33 | stealing,64 | guided,1 | static | ...)");
                std::process::exit(2);
            }
        }
    }
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "run" => cmd_run(&args),
        "figure" | "table" => {
            let name = args.positional.get(1).map(String::as_str).unwrap_or("");
            match harness::run_named(name) {
                Some(s) => println!("{s}"),
                None => {
                    eprintln!("unknown figure/table '{name}'; available: {:?}", harness::NAMES);
                    std::process::exit(2);
                }
            }
        }
        "summary" => println!("{}", harness::run_named("summary").unwrap()),
        "ablation" | "ablations" => println!("{}", harness::run_named("ablations").unwrap()),
        "sweep" => cmd_sweep(&args),
        "regret" => cmd_regret(&args),
        "overlap" => cmd_overlap(&args),
        "serve" => cmd_serve(&args),
        "analyze" => {
            // `--dir` points at an alternative crate root (a checkout-
            // relative path in CI); the default is this crate itself.
            let root = args
                .get("dir")
                .map(std::path::PathBuf::from)
                .unwrap_or_else(|| std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")));
            std::process::exit(ich::analysis::run(&root));
        }
        "lint-atomics" => {
            // `--dir` overrides the default (this crate's own src/),
            // so CI can point the lint at a checkout-relative path.
            let root = args
                .get("dir")
                .map(std::path::PathBuf::from)
                .unwrap_or_else(|| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src"));
            std::process::exit(ich::util::lint::run(&root));
        }
        "list" => cmd_list(),
        "version" => println!("ich 0.1.0 (paper: Booth & Lane 2020, iCh)"),
        _ => {
            println!("usage: ich <run|figure|table|summary|ablation|sweep|regret|overlap|serve|analyze|lint-atomics|list|version> [flags]");
            println!("  ich analyze  static concurrency-contract gate over src/sched, src/check,");
            println!("        src/coordinator: lock-order cycles, blocking in claim loops, the");
            println!("        claim-loop contract (preempt_point + note_assist + chunk accounting),");
            println!("        MEMORY_MODEL edge-ID drift, and the atomics/unsafe comment lint.");
            println!("        Silence one site with `// analysis: allow(<rule>, reason)` on or above");
            println!("        the line; above a fn header the allow covers the whole fn.");
            println!("  e.g.: ich run --app bfs-scale-free --sched ich,0.33 --threads 28");
            println!("        ich run --app spmv --sched guided,1 --threads 4 --real");
            println!("        ich run --app spmv --sched ich --threads 4 --real --steal uniform");
            println!("        ich overlap --threads 2 --jobs 4 --n 2000000");
            println!("        ich overlap --threads 2 --jobs 8 --class background");
            println!("        ich serve --tenants 3 --weight 4,2,1 --jobs 300 --arrivals 3000");
            println!("        ich serve --tenants 'gold:w=4:rate=500,bulk:depth=16' --virtual --seed 7");
            println!("  ich serve flags: --tenants <count|name[:w=][:rate=][:burst=][:depth=],...>");
            println!("        --rate/--burst/--depth (applied to every tenant), --weight w0,w1,...,");
            println!("        --jobs, --arrivals (Poisson submissions/s), --n, --threads, --workers,");
            println!("        --inflight (fair release window), --seed, --cost-ns, --out <path>,");
            println!("        --virtual (deterministic virtual clock + declared costs: zero sleeps,");
            println!("        identical output for identical seeds — the CI smoke mode)");
            println!("        ich figure fig4");
            println!("        ICH_TOPOLOGY='2x14@10,21;21,10' ich run --app spmv --sched ich --real --steal ranked");
            println!("  --steal uniform|topo|ranked  steal-victim policy (default: topo; env ICH_STEAL);");
            println!("        ranked draws victims with probability decaying per NUMA-distance tier");
            println!("  --policy <spec>  process-wide scheduling-policy default (env ICH_POLICY);");
            println!("        `auto` picks an engine per loop site online: a seeded deterministic");
            println!("        bandit keyed on (callsite, workload-feature bucket), e.g.");
            println!("        ich run --app spmv --policy auto --real");
            println!("  ICH_AUTO_SEED  exploration-hash seed for --policy auto (deterministic:");
            println!("        same seed + same observations => same choices)");
            println!("  ICH_AUTO_EXPLORE  exploration floor for --policy auto: one forced");
            println!("        exploration pick every N choices (default 32)");
            println!("  ich regret  Policy::Auto regret harness: --episodes (default 40), --seed,");
            println!("        --out (default results/BENCH_auto.json); converged_all must be true");
            println!("  --class interactive|batch|background  dispatch class (default: batch; env ICH_CLASS)");
            println!("  --assist on|off  work assisting (default: off; env ICH_ASSIST): idle pool workers");
            println!("        join in-flight loops and blocking submitters run chunks of their own epoch");
            println!("  ICH_TOPOLOGY  core->node map override: NxM | per-core list, with an optional");
            println!("        @-suffixed node-distance matrix (rows ';'-separated): 2x14@10,21;21,10");
            println!("  ICH_EDF_TICK  pin the EDF distance-penalty tick scale (default: one-shot");
            println!("        measured cross-socket calibration at pool startup on multi-socket");
            println!("        hosts; single-socket hosts stay at the neutral 1.0; clamped to 0.25-4)");
        }
    }
}

fn cmd_run(args: &Args) {
    let app_name = args.get_or("app", "synth-exp-dec");
    // No --sched: honor the process default (--policy / ICH_POLICY),
    // which is `ich` with the paper's parameters when unset.
    let default_sched = Policy::process_default().name();
    let sched = args.get_or("sched", &default_sched);
    let threads = args.get_usize("threads", 28);
    let seed = args.get_u64("seed", harness::figures::SEED);
    let Some(app) = apps::make_app(app_name, seed) else {
        eprintln!("unknown app '{app_name}'; available: {:?}", apps::APP_NAMES);
        std::process::exit(2);
    };
    let Some(policy) = Policy::parse(sched) else {
        eprintln!("unknown policy '{sched}'");
        std::process::exit(2);
    };
    if args.get_bool("real") {
        let r = app.run_real(&policy, threads, seed);
        println!(
            "app={} sched={} threads={} REAL time={:.4}s valid={} chunks={} steals={}ok/{}fail imbalance={:.3}",
            app.name(),
            policy.name(),
            threads,
            r.elapsed_s,
            r.valid,
            r.metrics.total_chunks,
            r.metrics.steals_ok,
            r.metrics.steals_failed,
            r.metrics.imbalance()
        );
        if !r.valid {
            std::process::exit(1);
        }
    } else {
        let spec = MachineSpec::default();
        let loops = app.sim_loops();
        let r = simulate_app(&spec, threads, &loops, &policy, seed);
        let t1 = simulate_app(&spec, 1, &loops, &Policy::Guided { chunk: 1 }, seed).time;
        println!(
            "app={} sched={} threads={} SIM time={:.0} speedup={:.2} chunks={} steals={}ok/{}fail",
            app.name(),
            policy.name(),
            threads,
            r.time,
            t1 / r.time,
            r.chunks,
            r.steals_ok,
            r.steals_fail
        );
    }
}

fn cmd_sweep(args: &Args) {
    let app_name = args.get_or("app", "synth-exp-dec");
    let seed = args.get_u64("seed", harness::figures::SEED);
    let threads = args.get_usize_list("threads", harness::speedup::THREADS);
    let Some(app) = apps::make_app(app_name, seed) else {
        eprintln!("unknown app '{app_name}'; available: {:?}", apps::APP_NAMES);
        std::process::exit(2);
    };
    let spec = MachineSpec::default();
    let loops = app.sim_loops();
    let mut t = Table::new(["policy", "p", "time", "speedup"]);
    let t_ref = harness::speedup::best_time(&spec, &loops, "guided", 1, seed);
    for fam in PAPER_FAMILIES {
        for pol in table2_grid(fam) {
            for &p in &threads {
                let tt = harness::speedup::sim_time(&spec, &loops, &pol, p, seed);
                t.row([pol.name(), p.to_string(), format!("{tt:.0}"), f2(t_ref / tt)]);
            }
        }
    }
    println!("# sweep: {} (simulated)\n{}", app.name(), t.render());
}

/// Regret harness for `Policy::Auto`: repeated episodes of each
/// evaluation app on each simulated machine model, checking that the
/// online selector's post-exploration mean lands within the
/// convergence bound of the best fixed engine's. Writes
/// `BENCH_auto.json` (the CI `policy-auto` job greps it).
fn cmd_regret(args: &Args) {
    let prm = harness::regret::RegretParams {
        episodes: args.get_usize("episodes", 40),
        seed: args.get_u64("seed", 7),
        out: args.get_or("out", "results/BENCH_auto.json").to_string(),
    };
    print!("{}", harness::regret::run(&prm));
}

/// Serve `--jobs` independent copies of a skewed synthetic loop, once
/// sequentially (one blocking fork-join after another) and once
/// overlapped (all submitted as async epochs up front), and report
/// both wall times. This is the serving-layer scenario the async
/// submission path exists for.
fn cmd_overlap(args: &Args) {
    let threads = args.get_usize("threads", 2);
    let jobs = args.get_usize("jobs", 4);
    let n = args.get_usize("n", 2_000_000);
    let sched = args.get_or("sched", "ich,0.33");
    let Some(policy) = Policy::parse(sched) else {
        eprintln!("unknown policy '{sched}'");
        std::process::exit(2);
    };
    // Skewed synthetic body: iteration i costs ~1 + (i % 64)/8 units.
    let body = |r: std::ops::Range<usize>| {
        let mut acc = 0u64;
        for i in r {
            for j in 0..(1 + (i % 64) / 8) {
                acc = acc.wrapping_add(i as u64 ^ j as u64);
            }
        }
        std::hint::black_box(acc);
    };

    let opts = ForOpts { threads, pin: false, seed: 1, weights: None, mode: ExecMode::Pool, ..Default::default() };
    // Warm the lazy global pool outside both timed regions so the
    // sequential arm doesn't pay the one-time worker spawn.
    parallel_for(1024, &policy, &opts, &body);
    let t0 = std::time::Instant::now();
    for j in 0..jobs {
        let m = parallel_for(n, &policy, &opts.clone().with_seed(j as u64), &body);
        assert_eq!(m.total_iters, n as u64);
    }
    let sequential_s = t0.elapsed().as_secs_f64();

    let coord = Coordinator::new(threads);
    let t0 = std::time::Instant::now();
    let job_list: Vec<LoopJob> = (0..jobs)
        .map(|j| LoopJob::new(&format!("job-{j}"), n, policy.clone(), std::sync::Arc::new(body)).with_seed(j as u64))
        .collect();
    let results = coord.run_overlapped(job_list);
    let overlapped_s = t0.elapsed().as_secs_f64();

    for (name, m) in &results {
        println!(
            "  {name}: class={} queue_wait={:.6}s{} iters={} chunks={} steals={}ok/{}fail imbalance={:.3}",
            m.class.name(),
            m.queue_wait_s,
            if m.promoted { " (promoted)" } else { "" },
            m.total_iters,
            m.total_chunks,
            m.steals_ok,
            m.steals_failed,
            m.imbalance()
        );
    }
    // Per-class dispatch counters of the shared pool (submissions,
    // dispatches, promotions, queue waits) for the whole command.
    for cs in ich::sched::Runtime::global().class_stats() {
        if cs.submitted > 0 {
            println!(
                "  class {}: submitted={} dispatched={} promotions={} queue_wait total={:.6}s max={:.6}s",
                cs.class.name(),
                cs.submitted,
                cs.dispatched,
                cs.promotions,
                cs.queue_wait_s_total,
                cs.queue_wait_s_max
            );
        }
    }
    println!(
        "jobs={jobs} n={n} threads={threads} sched={} class={}: sequential {sequential_s:.4}s vs overlapped {overlapped_s:.4}s ({:.2}x)",
        policy.name(),
        LatencyClass::process_default().name(),
        sequential_s / overlapped_s
    );
}

/// Sustained multi-tenant serving through the fair-share admission
/// front end (`sched::fair`): open-loop Poisson arrivals over mixed
/// tenants and classes, per-tenant p50/p99 queue waits, shed counts,
/// and Jain's fairness index, recorded to `BENCH_serving.json`.
fn cmd_serve(args: &Args) {
    let p = match harness::serving::params_from_args(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("serve: {e}");
            std::process::exit(2);
        }
    };
    let specs: Vec<String> = p.tenants.iter().map(|t| t.spec_string()).collect();
    println!(
        "serve: {} jobs at {}/s over {} tenants ({} clock, inflight {})",
        p.jobs,
        p.arrival_rate,
        p.tenants.len(),
        if p.virtual_clock { "virtual" } else { "real" },
        p.inflight
    );
    for s in &specs {
        println!("  tenant {s}");
    }
    let r = harness::serving::run_serving(&p);
    let mut t = Table::new(["tenant", "w", "submitted", "completed", "queued", "shed", "wait p50", "wait p99"]);
    for tr in &r.tenants {
        t.row([
            tr.name.clone(),
            tr.weight.to_string(),
            tr.submitted.to_string(),
            tr.completed.to_string(),
            tr.queued.to_string(),
            format!("{}+{}", tr.shed_throttled, tr.shed_full),
            format!("{:.3}ms", tr.wait_p50_ns as f64 / 1e6),
            format!("{:.3}ms", tr.wait_p99_ns as f64 / 1e6),
        ]);
    }
    println!("{}", t.render());
    println!(
        "jain raw={:.4} weighted={:.4} elapsed={:.3}s clock={:.3}s",
        r.jain_raw,
        r.jain_weighted,
        r.elapsed_s,
        r.clock_ns as f64 / 1e9
    );
    let json = harness::serving::report_json(&p, &r);
    match json.save(&p.out) {
        Ok(()) => println!("wrote {}", p.out),
        Err(e) => eprintln!("could not write {}: {e}", p.out),
    }
}

fn cmd_list() {
    println!("apps:     {:?}", apps::APP_NAMES);
    println!("families: {PAPER_FAMILIES:?} (+ static, factoring, awf, hss)");
    println!("figures:  {:?}", harness::NAMES);
}
