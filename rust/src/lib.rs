//! # iCh — An Adaptive Self-Scheduling Loop Scheduler
//!
//! Reproduction of Booth & Lane, *"An Adaptive Self-Scheduling Loop
//! Scheduler"* (2020): a loop-scheduling runtime whose headline policy,
//! **iCh**, self-manages per-thread chunk size from a running estimate
//! of iteration-throughput spread and recovers imbalance with
//! THE-protocol work-stealing.
//!
//! The crate is organized as the three-layer Rust+JAX+Pallas stack
//! described in `DESIGN.md`:
//!
//! - [`sched`] — the L3 coordinator: `parallel_for` with pluggable
//!   self-scheduling policies (iCh + all the paper's baselines), plus
//!   `parallel_for_async` for non-blocking epoch submission.
//! - [`coordinator`] — the L4 serving layer: overlap independent
//!   loops from many submitters on the shared persistent pool.
//! - [`sim`] — a discrete-event simulated 28-thread NUMA machine that
//!   reruns the same policy math in virtual time (this reproduces the
//!   paper's speedup figures on hardware we don't have).
//! - [`apps`] — the five evaluation applications (synth, BFS, K-Means,
//!   LavaMD, SpMV) over the [`graph`]/[`sparse`] substrates.
//! - [`runtime`] — the PJRT bridge that loads the AOT-compiled JAX/
//!   Pallas kernels (`artifacts/*.hlo.txt`) and executes them from the
//!   Rust hot path; Python never runs at request time.
//! - [`harness`] — experiment drivers regenerating every table and
//!   figure of the paper's evaluation.
//! - [`analysis`] — the in-house static concurrency-contract analyzer
//!   behind `ich analyze` (lock order, claim-loop contracts,
//!   MEMORY_MODEL drift); a tier-1 CI gate.

pub mod analysis;
pub mod apps;
#[cfg(any(test, feature = "check"))]
pub mod check;
pub mod coordinator;
pub mod graph;
pub mod harness;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sparse;
pub mod util;

pub use sched::{
    parallel_for, parallel_for_async, parallel_for_each, ExecMode, ForOpts, IchParams, LatencyClass, LoopJoin,
    Policy, Runtime, VictimPolicy,
};
