//! The four concurrency-contract rule families.
//!
//! 1. `lock-order` — propagate held-lock sets through the call graph,
//!    build the global acquisition-order graph, fail on cycles (both
//!    witnessing paths are printed).
//! 2. `claim-blocking` — no blocking call (Mutex/Condvar/join/park/…)
//!    may be reachable from an engine claim loop, nor sit inside a
//!    deque-lock critical section.
//! 3. `claim-contract` — every `run_assistable` caller must reach
//!    `preempt_point()`, assist accounting (`note_assist`) and a
//!    member/assist metrics-partition call.
//! 4. `order-drift` — `// order:` comments and the MEMORY_MODEL.md
//!    edge registry must stay bidirectionally live.

use std::collections::{BTreeMap, HashMap, HashSet};

use super::facts::Crate;
use super::Finding;

pub const RULE_LOCK_ORDER: &str = "lock-order";
pub const RULE_CLAIM_BLOCKING: &str = "claim-blocking";
pub const RULE_CLAIM_CONTRACT: &str = "claim-contract";
pub const RULE_ORDER_DRIFT: &str = "order-drift";

/// Marker every annotated atomic site carries.
const ORDER_MARK: &str = "// order: ";

fn start_of(c: &Crate, id: usize) -> usize {
    c.item_of(id).start
}

/// Rule 1: lock-order consistency.
pub fn lock_order(c: &Crate, out: &mut Vec<Finding>) {
    // Fix-point of "locks this fn may (transitively) acquire".
    let n = c.facts.len();
    let mut may: Vec<HashSet<String>> = vec![HashSet::new(); n];
    let mut skip = vec![false; n];
    for id in 0..n {
        let fm = c.file_of(id);
        skip[id] = fm.fn_allowed(RULE_LOCK_ORDER, start_of(c, id));
        if skip[id] {
            continue;
        }
        for (lid, line, _) in &c.facts[id].acquires {
            if !fm.allowed(RULE_LOCK_ORDER, *line, Some(start_of(c, id))) {
                may[id].insert(lid.clone());
            }
        }
    }
    loop {
        let mut changed = false;
        for id in 0..n {
            if skip[id] {
                continue;
            }
            let mut add: Vec<String> = Vec::new();
            for call in &c.facts[id].calls {
                for tgt in c.resolve(id, call) {
                    if skip[tgt] {
                        continue;
                    }
                    for l in &may[tgt] {
                        if !may[id].contains(l) {
                            add.push(l.clone());
                        }
                    }
                }
            }
            for l in add {
                if may[id].insert(l) {
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
    // Acquisition-order edges with witnesses.
    let mut edges: BTreeMap<(String, String), String> = BTreeMap::new();
    for id in 0..n {
        if skip[id] {
            continue;
        }
        let fm = c.file_of(id);
        let item = c.item_of(id);
        // (lock id, min depth the guard survives at, binding, line)
        let mut held: Vec<(String, usize, Option<String>, usize)> = Vec::new();
        let mut acq_by_line: HashMap<usize, Vec<(String, bool)>> = HashMap::new();
        for (lid, line, guarded) in &c.facts[id].acquires {
            acq_by_line.entry(*line).or_default().push((lid.clone(), *guarded));
        }
        let mut calls_by_line: HashMap<usize, Vec<usize>> = HashMap::new();
        for call in &c.facts[id].calls {
            let mut tgts = c.resolve(id, call);
            tgts.retain(|t| !skip[*t]);
            calls_by_line.entry(call.line).or_default().extend(tgts);
        }
        for i in item.start..=item.end {
            let d = fm.depth_start[i];
            held.retain(|h| d >= h.1);
            let code = fm.lines[i].code.as_str();
            if let Some(p) = code.find("drop(") {
                let inner: String = code[p + 5..]
                    .chars()
                    .take_while(|ch| *ch != ')')
                    .collect::<String>()
                    .trim()
                    .to_string();
                held.retain(|h| h.2.as_deref() != Some(inner.as_str()));
            }
            let site_allowed = fm.allowed(RULE_LOCK_ORDER, i, Some(item.start));
            if !site_allowed {
                if let Some(acqs) = acq_by_line.get(&i) {
                    for (lid, _) in acqs {
                        for h in &held {
                            if &h.0 != lid {
                                edges.entry((h.0.clone(), lid.clone())).or_insert_with(|| {
                                    format!(
                                        "{}:{} in `{}` (holds `{}` since line {})",
                                        fm.rel,
                                        i + 1,
                                        item.qual_name(),
                                        h.0,
                                        h.3 + 1
                                    )
                                });
                            }
                        }
                    }
                }
                if let Some(tgts) = calls_by_line.get(&i) {
                    for &tgt in tgts {
                        for lid in &may[tgt] {
                            for h in &held {
                                if &h.0 != lid {
                                    edges.entry((h.0.clone(), lid.clone())).or_insert_with(|| {
                                        format!(
                                            "{}:{} in `{}` via `{}` (holds `{}` since line {})",
                                            fm.rel,
                                            i + 1,
                                            item.qual_name(),
                                            c.item_of(tgt).qual_name(),
                                            h.0,
                                            h.3 + 1
                                        )
                                    });
                                }
                            }
                        }
                    }
                }
            }
            // New guards opened on this line.
            if let Some(binding) = super::facts::guard_binding(code) {
                if let Some(acqs) = acq_by_line.get(&i) {
                    if let Some((lid, _)) = acqs.first() {
                        held.push((lid.clone(), fm.depth_start[i], Some(binding), i));
                    }
                }
            } else if super::facts::match_guard(code) {
                if let Some(acqs) = acq_by_line.get(&i) {
                    if let Some((lid, _)) = acqs.first() {
                        held.push((lid.clone(), fm.depth_start[i] + 1, None, i));
                    }
                }
            }
        }
    }
    // Cycle search over the lock graph.
    let mut graph: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (a, b) in edges.keys() {
        graph.entry(a.as_str()).or_default().push(b.as_str());
    }
    if let Some(cyc) = find_cycle(&graph) {
        let path = cyc.join(" -> ");
        let wit: Vec<String> = cyc
            .windows(2)
            .map(|w| edges[&(w[0].to_string(), w[1].to_string())].clone())
            .collect();
        out.push(Finding {
            file: "(crate)".to_string(),
            line: 0,
            rule: RULE_LOCK_ORDER,
            msg: format!("lock-order cycle {path}; witnesses: {}", wit.join("; ")),
        });
    }
}

fn find_cycle(graph: &BTreeMap<&str, Vec<&str>>) -> Option<Vec<String>> {
    // 0 = white, 1 = on stack, 2 = done
    let mut state: HashMap<&str, u8> = HashMap::new();
    for &root in graph.keys() {
        if state.get(root).copied().unwrap_or(0) != 0 {
            continue;
        }
        let mut stack: Vec<&str> = Vec::new();
        if let Some(cyc) = dfs(root, graph, &mut state, &mut stack) {
            return Some(cyc);
        }
    }
    None
}

fn dfs<'a>(
    u: &'a str,
    graph: &BTreeMap<&'a str, Vec<&'a str>>,
    state: &mut HashMap<&'a str, u8>,
    stack: &mut Vec<&'a str>,
) -> Option<Vec<String>> {
    state.insert(u, 1);
    stack.push(u);
    if let Some(vs) = graph.get(u) {
        for &v in vs {
            match state.get(v).copied().unwrap_or(0) {
                1 => {
                    let k = stack.iter().position(|x| *x == v).unwrap_or(0);
                    let mut cyc: Vec<String> = stack[k..].iter().map(|s| s.to_string()).collect();
                    cyc.push(v.to_string());
                    return Some(cyc);
                }
                0 => {
                    if let Some(cyc) = dfs(v, graph, state, stack) {
                        return Some(cyc);
                    }
                }
                _ => {}
            }
        }
    }
    stack.pop();
    state.insert(u, 2);
    None
}

/// Call-graph closure from `roots`, pruned at fn-level allows.
fn reachable(c: &Crate, roots: &[usize], rule: &str) -> Vec<usize> {
    let mut seen: HashSet<usize> = HashSet::new();
    let mut work: Vec<usize> = roots.to_vec();
    while let Some(id) = work.pop() {
        if seen.contains(&id) {
            continue;
        }
        if c.file_of(id).fn_allowed(rule, start_of(c, id)) {
            continue;
        }
        seen.insert(id);
        for call in &c.facts[id].calls {
            for tgt in c.resolve(id, call) {
                if !seen.contains(&tgt) {
                    work.push(tgt);
                }
            }
        }
    }
    let mut v: Vec<usize> = seen.into_iter().collect();
    v.sort_unstable();
    v
}

/// Rule 2: no blocking inside claim loops or deque-lock sections.
pub fn claim_blocking(c: &Crate, out: &mut Vec<Finding>) {
    let roots: Vec<usize> = (0..c.facts.len()).filter(|&id| c.facts[id].has_preempt).collect();
    for id in reachable(c, &roots, RULE_CLAIM_BLOCKING) {
        let fm = c.file_of(id);
        let item = c.item_of(id);
        for (label, line) in &c.facts[id].blocking {
            if fm.allowed(RULE_CLAIM_BLOCKING, *line, Some(item.start)) {
                continue;
            }
            out.push(Finding {
                file: fm.rel.clone(),
                line: line + 1,
                rule: RULE_CLAIM_BLOCKING,
                msg: format!("blocking call ({label}) reachable from a claim loop, in `{}`", item.qual_name()),
            });
        }
    }
    // Sub-rule: nothing blocking while a deque lock guard is live.
    for id in 0..c.facts.len() {
        let fm = c.file_of(id);
        let item = c.item_of(id);
        for (lid, gline, guarded) in &c.facts[id].acquires {
            if !guarded || lid != "lock" {
                continue;
            }
            let d0 = fm.depth_start[*gline];
            for (label, line) in &c.facts[id].blocking {
                if line <= gline || fm.depth_start[*line] < d0 {
                    continue;
                }
                if fm.allowed(RULE_CLAIM_BLOCKING, *line, Some(item.start)) {
                    continue;
                }
                out.push(Finding {
                    file: fm.rel.clone(),
                    line: line + 1,
                    rule: RULE_CLAIM_BLOCKING,
                    msg: format!(
                        "blocking call ({label}) while the deque lock (line {}) is held, in `{}`",
                        gline + 1,
                        item.qual_name()
                    ),
                });
            }
        }
    }
}

/// Rule 3: the structural claim-loop contract.
pub fn claim_contract(c: &Crate, out: &mut Vec<Finding>) {
    for id in 0..c.facts.len() {
        if !c.facts[id].has_run_assistable {
            continue;
        }
        let fm = c.file_of(id);
        let item = c.item_of(id);
        if item.name == "run_assistable" {
            continue; // the runtime's own definition site
        }
        if fm.allowed(RULE_CLAIM_CONTRACT, item.start, Some(item.start)) {
            continue;
        }
        let seen = reachable(c, &[id], RULE_CLAIM_CONTRACT);
        let has_p = seen.iter().any(|&t| c.facts[t].has_preempt);
        let has_n = seen.iter().any(|&t| c.facts[t].has_note_assist);
        let has_c = seen.iter().any(|&t| c.facts[t].has_chunk_acct);
        let mut missing: Vec<&str> = Vec::new();
        if !has_p {
            missing.push("preempt_point()");
        }
        if !has_n {
            missing.push("note_assist() assist accounting");
        }
        if !has_c {
            missing.push("metrics partition (add_chunk_at/add_bulk/add_assist_bulk)");
        }
        if !missing.is_empty() {
            out.push(Finding {
                file: fm.rel.clone(),
                line: item.start + 1,
                rule: RULE_CLAIM_CONTRACT,
                msg: format!("claim loop `{}` missing: {}", item.qual_name(), missing.join(", ")),
            });
        }
    }
}

/// Parse the edge-ID registry table out of MEMORY_MODEL.md: rows of
/// the form `| `edge.id` | … |`. Returns id -> 1-based line.
pub fn parse_registry(md: &str) -> BTreeMap<String, usize> {
    let mut ids = BTreeMap::new();
    for (i, line) in md.split('\n').enumerate() {
        let t = line.trim_start();
        let Some(rest) = t.strip_prefix('|') else { continue };
        let cell = rest.trim_start();
        let Some(body) = cell.strip_prefix('`') else { continue };
        let Some(end) = body.find('`') else { continue };
        let id = &body[..end];
        if id == "edge-id" || id.is_empty() {
            continue;
        }
        if id.chars().all(|ch| ch.is_ascii_lowercase() || ch.is_ascii_digit() || ch == '.' || ch == '-' || ch == '_') {
            ids.entry(id.to_string()).or_insert(i + 1);
        }
    }
    ids
}

/// Rule 4: MEMORY_MODEL drift, both directions.
pub fn order_drift(c: &Crate, registry: &BTreeMap<String, usize>, md_rel: &str, out: &mut Vec<Finding>) {
    let mut used: HashMap<&str, usize> = HashMap::new();
    for fm in &c.files {
        for (i, raw) in fm.raw.iter().enumerate() {
            let Some(idx) = raw.find(ORDER_MARK) else { continue };
            // Skip doc comments (`/// order:`) and quoted mentions.
            if idx > 0 {
                let prev = raw.as_bytes()[idx - 1];
                if prev == b'/' || prev == b'`' {
                    continue;
                }
            }
            let text = &raw[idx + ORDER_MARK.len()..];
            let Some(body) = text.strip_prefix('[') else {
                if !fm.allowed(RULE_ORDER_DRIFT, i, None) {
                    out.push(Finding {
                        file: fm.rel.clone(),
                        line: i + 1,
                        rule: RULE_ORDER_DRIFT,
                        msg: "order comment lacks a `[edge-id]` registry reference".to_string(),
                    });
                }
                continue;
            };
            let Some(end) = body.find(']') else {
                out.push(Finding {
                    file: fm.rel.clone(),
                    line: i + 1,
                    rule: RULE_ORDER_DRIFT,
                    msg: "unterminated `[edge-id]` in order comment".to_string(),
                });
                continue;
            };
            let id = &body[..end];
            match registry.get_key_value(id) {
                Some((k, _)) => {
                    *used.entry(k.as_str()).or_insert(0) += 1;
                }
                None => {
                    if !fm.allowed(RULE_ORDER_DRIFT, i, None) {
                        out.push(Finding {
                            file: fm.rel.clone(),
                            line: i + 1,
                            rule: RULE_ORDER_DRIFT,
                            msg: format!("order comment names unknown edge id `{id}`"),
                        });
                    }
                }
            }
        }
    }
    for (id, line) in registry {
        if used.get(id.as_str()).copied().unwrap_or(0) == 0 {
            out.push(Finding {
                file: md_rel.to_string(),
                line: *line,
                rule: RULE_ORDER_DRIFT,
                msg: format!("documented edge `{id}` has zero live `// order:` sites"),
            });
        }
    }
}
