//! `ich analyze` — whole-crate static concurrency-contract analyzer.
//!
//! A zero-dependency pipeline (this crate has no proc-macro or AST
//! library, so the analyzer ships its own): [`lex`] blanks literals
//! and comments, [`parse`] recovers `fn` items with `impl` types and
//! brace depths, [`facts`] extracts per-function facts and builds the
//! crate-wide call-graph index, and [`rules`] enforces four contract
//! families over `src/sched/`, `src/check/` and `src/coordinator/`:
//!
//! - **lock-order** — held-lock sets propagate through the call graph
//!   into a global acquisition-order graph; any cycle fails CI with
//!   both witnessing paths.
//! - **claim-blocking** — no `Mutex::lock`, `Condvar::wait`, `join()`,
//!   `park`, `sleep` or channel `recv` may be transitively reachable
//!   from an engine claim loop (any fn containing `preempt_point()`),
//!   nor sit inside a deque-lock critical section.
//! - **claim-contract** — every `run_assistable` caller must
//!   structurally reach `preempt_point()`, assist-gate accounting
//!   (`note_assist`) and a metrics-partition call (`add_chunk_at` /
//!   `add_bulk` / `add_assist_bulk` / `add_chunk`).
//! - **order-drift** — every `// order:` comment must carry a
//!   `[edge-id]` registered in `sched/MEMORY_MODEL.md`, unknown IDs
//!   fail, and registered edges with zero live sites fail (the doc
//!   and the code cannot drift apart silently).
//!
//! A fifth rule, **lint-atomics**, folds the pre-existing
//! [`crate::util::lint`] conventions in: `src/` is linted strictly
//! (atomics need `// order:`, `unsafe` needs `// SAFETY:`), the
//! `tests/` tree relaxed (`// SAFETY:` only — test code observes
//! atomics, it doesn't build protocols).
//!
//! False positives are silenced in place, never globally:
//!
//! ```text
//! // analysis: allow(<rule>[, reason])
//! ```
//!
//! on (or directly above) the offending line suppresses that rule at
//! that site; directly above a `fn` it suppresses the rule for the
//! whole fn *and* stops call-graph traversal into it. The reason text
//! is free-form (no `)` allowed) and shows up in `git grep` audits.

pub mod facts;
pub mod lex;
pub mod parse;
pub mod rules;

use std::fs;
use std::path::Path;

use facts::{Crate, FileModel};

/// One analyzer finding at `file:line`.
#[derive(Debug)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Library entry point (also what the fixture tests drive): analyze a
/// set of `(relative-path, source)` pairs. The order-drift rule only
/// runs when `registry_md` (the MEMORY_MODEL.md text) is provided;
/// `md_rel` names it in findings.
pub fn analyze_sources(sources: &[(String, String)], registry_md: Option<&str>, md_rel: &str) -> Vec<Finding> {
    let files: Vec<FileModel> = sources.iter().map(|(rel, src)| FileModel::new(rel, src)).collect();
    let c = Crate::build(files);
    let mut out = Vec::new();
    rules::lock_order(&c, &mut out);
    rules::claim_blocking(&c, &mut out);
    rules::claim_contract(&c, &mut out);
    if let Some(md) = registry_md {
        let registry = rules::parse_registry(md);
        rules::order_drift(&c, &registry, md_rel, &mut out);
    }
    out.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    out
}

/// The directories (relative to the crate's `src/`) the concurrency
/// rules cover: the scheduler core, its model checker, and the
/// serving-layer coordinator.
pub const SCOPE: &[&str] = &["sched", "check", "coordinator"];

fn collect_rs(dir: &Path, rel_prefix: &str, out: &mut Vec<(String, String)>) -> std::io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.as_ref().map(|e| e.path()).unwrap_or_default());
    for e in entries {
        let e = e?;
        let p = e.path();
        let name = e.file_name().to_string_lossy().to_string();
        if p.is_dir() {
            collect_rs(&p, &format!("{rel_prefix}{name}/"), out)?;
        } else if name.ends_with(".rs") {
            out.push((format!("{rel_prefix}{name}"), fs::read_to_string(&p)?));
        }
    }
    Ok(())
}

/// CLI driver for `ich analyze`: run all five rule families over the
/// crate rooted at `manifest_dir`. Prints findings `file:line: [rule]
/// msg` and returns the process exit code (0 clean, 1 findings, 2
/// I/O trouble).
pub fn run(manifest_dir: &Path) -> i32 {
    let src_dir = manifest_dir.join("src");
    let mut sources: Vec<(String, String)> = Vec::new();
    for scope in SCOPE {
        let dir = src_dir.join(scope);
        if !dir.is_dir() {
            continue;
        }
        if let Err(e) = collect_rs(&dir, &format!("src/{scope}/"), &mut sources) {
            eprintln!("analyze: cannot read {}: {e}", dir.display());
            return 2;
        }
    }
    let md_path = src_dir.join("sched").join("MEMORY_MODEL.md");
    let registry_md = match fs::read_to_string(&md_path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("analyze: cannot read {}: {e}", md_path.display());
            return 2;
        }
    };
    let mut findings = analyze_sources(&sources, Some(&registry_md), "src/sched/MEMORY_MODEL.md");

    // Rule family 5: the atomics/unsafe comment lint, strict over
    // src/, relaxed over tests/ (known-bad analyzer fixtures skipped).
    let skip = ["analysis_fixtures"];
    match crate::util::lint::scan_dir_with(&src_dir, true, &skip) {
        Ok(vs) => findings.extend(vs.into_iter().map(|v| Finding {
            file: format!("src/{}", v.file),
            line: v.line,
            rule: "lint-atomics",
            msg: v.message,
        })),
        Err(e) => {
            eprintln!("analyze: lint over {}: {e}", src_dir.display());
            return 2;
        }
    }
    let tests_dir = manifest_dir.join("tests");
    if tests_dir.is_dir() {
        match crate::util::lint::scan_dir_with(&tests_dir, false, &skip) {
            Ok(vs) => findings.extend(vs.into_iter().map(|v| Finding {
                file: format!("tests/{}", v.file),
                line: v.line,
                rule: "lint-atomics",
                msg: v.message,
            })),
            Err(e) => {
                eprintln!("analyze: lint over {}: {e}", tests_dir.display());
                return 2;
            }
        }
    }

    if findings.is_empty() {
        let n_files = sources.len();
        println!("analyze: clean ({n_files} files, rules: lock-order claim-blocking claim-contract order-drift lint-atomics)");
        0
    } else {
        for f in &findings {
            eprintln!("{f}");
        }
        eprintln!("analyze: {} finding(s)", findings.len());
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(files: &[(&str, &str)]) -> Vec<(String, String)> {
        files.iter().map(|(a, b)| (a.to_string(), b.to_string())).collect()
    }

    #[test]
    fn clean_input_has_no_findings() {
        let files = src(&[(
            "src/sched/a.rs",
            "fn claim(shared: &S) {\n    preempt_point();\n    shared.n.fetch_add(1, Ordering::Relaxed); // order: [e.one] bump\n}\n",
        )]);
        let md = "| `e.one` | bump | test |\n";
        let v = analyze_sources(&files, Some(md), "MM.md");
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn lock_order_cycle_is_reported_with_witnesses() {
        let files = src(&[(
            "src/sched/a.rs",
            concat!(
                "fn fwd(s: &S) {\n",
                "    let g = s.alpha.lock().unwrap();\n",
                "    take_beta(s);\n",
                "}\n",
                "fn take_beta(s: &S) {\n",
                "    let h = s.beta.lock().unwrap();\n",
                "    drop(h);\n",
                "}\n",
                "fn rev(s: &S) {\n",
                "    let h = s.beta.lock().unwrap();\n",
                "    let g = s.alpha.lock().unwrap();\n",
                "}\n",
            ),
        )]);
        let v = analyze_sources(&files, None, "");
        let cyc: Vec<&Finding> = v.iter().filter(|f| f.rule == rules::RULE_LOCK_ORDER).collect();
        assert_eq!(cyc.len(), 1, "{v:?}");
        assert!(cyc[0].msg.contains("alpha") && cyc[0].msg.contains("beta"));
        assert!(cyc[0].msg.contains("witnesses:"));
    }

    #[test]
    fn blocking_reachable_from_claim_loop_is_reported() {
        let files = src(&[(
            "src/sched/a.rs",
            concat!(
                "fn claim(s: &S) {\n",
                "    preempt_point();\n",
                "    helper(s);\n",
                "}\n",
                "fn helper(s: &S) {\n",
                "    std::thread::park();\n",
                "}\n",
            ),
        )]);
        let v = analyze_sources(&files, None, "");
        assert!(
            v.iter().any(|f| f.rule == rules::RULE_CLAIM_BLOCKING && f.msg.contains("park")),
            "{v:?}"
        );
    }

    #[test]
    fn allow_directive_suppresses_a_site() {
        let files = src(&[(
            "src/sched/a.rs",
            concat!(
                "fn claim(s: &S) {\n",
                "    preempt_point();\n",
                "    // analysis: allow(claim-blocking, test fixture)\n",
                "    std::thread::park();\n",
                "}\n",
            ),
        )]);
        let v = analyze_sources(&files, None, "");
        assert!(v.iter().all(|f| f.rule != rules::RULE_CLAIM_BLOCKING), "{v:?}");
    }

    #[test]
    fn claim_contract_missing_parts_reported() {
        let files = src(&[(
            "src/sched/eng.rs",
            "fn run(s: &S) {\n    s.rt.run_assistable(&claim);\n}\nfn claim(s: &S) {\n    s.x(1);\n}\n",
        )]);
        let v = analyze_sources(&files, None, "");
        let hit: Vec<&Finding> = v.iter().filter(|f| f.rule == rules::RULE_CLAIM_CONTRACT).collect();
        assert_eq!(hit.len(), 1, "{v:?}");
        assert!(hit[0].msg.contains("preempt_point"));
        assert!(hit[0].msg.contains("note_assist"));
    }

    #[test]
    fn order_drift_unknown_and_zero_site_ids() {
        let files = src(&[(
            "src/sched/a.rs",
            "fn f(s: &S) {\n    s.n.store(1, Ordering::Release); // order: [e.ghost] publish\n}\n",
        )]);
        let md = "| `e.real` | documented but unused | test |\n";
        let v = analyze_sources(&files, Some(md), "MM.md");
        assert!(v.iter().any(|f| f.rule == rules::RULE_ORDER_DRIFT && f.msg.contains("e.ghost")), "{v:?}");
        assert!(v.iter().any(|f| f.rule == rules::RULE_ORDER_DRIFT && f.msg.contains("e.real")), "{v:?}");
    }
}
