//! Per-function facts (lock acquisitions, calls, blocking sites,
//! claim-loop contract markers) plus the crate-wide call-graph index
//! the rules propagate over. All scans run on [`super::lex`]-cleaned
//! code, so literals and comments can't fake a site.

use std::collections::HashMap;

use super::lex::{clean_lines, is_word, CleanLine};
use super::parse::{parse_fns, FnItem};

/// A resolved-later call site: `qual::name(...)` or bare `name(...)`.
pub struct Call {
    pub qual: Option<String>,
    pub name: String,
    pub line: usize,
}

/// Everything a rule needs to know about one function body.
#[derive(Default)]
pub struct Facts {
    /// (lock identity, line, bound-to-a-guard).
    pub acquires: Vec<(String, usize, bool)>,
    pub calls: Vec<Call>,
    /// (what, line) — sites matching a known blocking pattern.
    pub blocking: Vec<(&'static str, usize)>,
    pub has_preempt: bool,
    pub has_run_assistable: bool,
    pub has_note_assist: bool,
    pub has_chunk_acct: bool,
}

/// Identifier ending right before byte `end` (exclusive), walking
/// back over `[A-Za-z0-9_.]` and trimming to a valid chain.
fn chain_before(code: &str, end: usize) -> Option<&str> {
    let bytes = code.as_bytes();
    let mut s = end;
    while s > 0 {
        let c = bytes[s - 1] as char;
        if is_word(c) || c == '.' {
            s -= 1;
        } else {
            break;
        }
    }
    while s < end && !(bytes[s] as char).is_ascii_alphabetic() && bytes[s] != b'_' {
        s += 1; // chain must start with a letter or `_`
    }
    if s < end {
        Some(&code[s..end])
    } else {
        None
    }
}

/// Final path segment of a lock chain: `self.shared.queue` -> `queue`.
pub fn lock_id(chain: &str) -> String {
    chain.rsplit('.').next().unwrap_or(chain).to_string()
}

/// `let [mut] <g> = <expr>.lock()[.unwrap()|.expect(..)];` — a guard
/// bound for the rest of the enclosing block. Returns the binding.
pub fn guard_binding(code: &str) -> Option<String> {
    let t = code.trim();
    let rest = t.strip_prefix("let ")?;
    let rest = rest.trim_start();
    let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
    let bytes = rest.as_bytes();
    let mut k = 0;
    while k < bytes.len() && is_word(bytes[k] as char) {
        k += 1;
    }
    if k == 0 {
        return None;
    }
    let name = &rest[..k];
    let tail = rest[k..].trim_start();
    let tail = tail.strip_prefix('=')?;
    if tail.contains(';') && !tail.trim_end().ends_with(';') {
        return None;
    }
    let mid = tail.trim().strip_suffix(';')?.trim_end();
    let p = mid.find(".lock()")?;
    let after = &mid[p + 7..];
    let whole = after.is_empty()
        || after == ".unwrap()"
        || (after.starts_with(".expect(") && after.ends_with(')') && !after[8..after.len() - 1].contains(')'));
    if whole {
        Some(name.to_string())
    } else {
        None
    }
}

/// `match <expr>.lock()` / `if let .. = <expr>.lock()` — a guard
/// scoped to the match/if body opened on this line.
pub fn match_guard(code: &str) -> bool {
    if !code.contains(".lock()") {
        return false;
    }
    has_token(code, "match") || code.contains("if let ")
}

/// Word-boundary token search.
pub fn has_token(code: &str, tok: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0usize;
    while let Some(p) = code[from..].find(tok).map(|p| p + from) {
        from = p + tok.len();
        let pre_ok = p == 0 || !is_word(bytes[p - 1] as char);
        let post = p + tok.len();
        let post_ok = post >= bytes.len() || !is_word(bytes[post] as char);
        if pre_ok && post_ok {
            return true;
        }
    }
    false
}

/// All `<pat>` occurrences whose preceding char is not a word char
/// (so `unpark(` never matches `park(`).
fn bounded_hits(code: &str, pat: &str) -> usize {
    let bytes = code.as_bytes();
    let mut from = 0usize;
    let mut hits = 0usize;
    while let Some(p) = code[from..].find(pat).map(|p| p + from) {
        from = p + pat.len();
        if p == 0 || !is_word(bytes[p - 1] as char) {
            hits += 1;
        }
    }
    hits
}

/// Blocking patterns rule 2 hunts for. `.lock(` is matched separately
/// through the acquisition scan so it shares the guard bookkeeping.
const BLOCKING_METHODS: [(&str, &str); 6] = [
    (".wait(", "Condvar::wait"),
    (".wait_timeout(", "Condvar::wait_timeout"),
    (".wait_while(", "Condvar::wait_while"),
    (".join()", "join()"),
    (".recv(", "channel recv"),
    (".recv_timeout(", "channel recv_timeout"),
];
const BLOCKING_FREE: [(&str, &str); 4] = [
    ("park(", "thread::park"),
    ("park_timeout(", "thread::park_timeout"),
    ("sleep(", "sleep"),
    ("join_wait(", "join_wait"),
];

/// Extract facts for one fn body (signature line through close brace).
pub fn extract_facts(lines: &[CleanLine], f: &FnItem) -> Facts {
    let mut fx = Facts::default();
    for i in f.start..=f.end {
        let code = lines[i].code.as_str();
        // lock acquisitions (also double as blocking sites)
        let mut from = 0usize;
        while let Some(p) = code[from..].find(".lock(").map(|p| p + from) {
            from = p + 6;
            if let Some(chain) = chain_before(code, p) {
                let guarded = guard_binding(code).is_some() || match_guard(code);
                fx.acquires.push((lock_id(chain), i, guarded));
                fx.blocking.push(("Mutex::lock", i));
            }
        }
        for (pat, label) in BLOCKING_METHODS {
            let mut from = 0usize;
            while let Some(p) = code[from..].find(pat).map(|p| p + from) {
                from = p + pat.len();
                fx.blocking.push((label, i));
            }
        }
        for (pat, label) in BLOCKING_FREE {
            for _ in 0..bounded_hits(code, pat) {
                fx.blocking.push((label, i));
            }
        }
        scan_calls(code, i, &mut fx.calls);
        if code.contains("preempt_point(") {
            fx.has_preempt = true;
        }
        if code.contains("run_assistable(") {
            fx.has_run_assistable = true;
        }
        if code.contains("note_assist(") {
            fx.has_note_assist = true;
        }
        for pat in ["add_chunk_at(", "add_bulk(", "add_assist_bulk(", "add_chunk("] {
            if code.contains(pat) {
                fx.has_chunk_acct = true;
            }
        }
    }
    fx
}

/// Rust keywords and binding forms that look like bare calls.
fn is_keyword(s: &str) -> bool {
    matches!(
        s,
        "as" | "async" | "await" | "box" | "break" | "const" | "continue" | "crate" | "dyn"
            | "else" | "enum" | "extern" | "false" | "fn" | "for" | "if" | "impl" | "in"
            | "let" | "loop" | "match" | "mod" | "move" | "mut" | "pub" | "ref" | "return"
            | "static" | "struct" | "super" | "trait" | "true" | "type" | "union" | "use"
            | "where" | "while"
    )
}

/// Collect qualified (`Q::name(`) and bare (`name(`) call sites.
fn scan_calls(code: &str, line: usize, out: &mut Vec<Call>) {
    let bytes = code.as_bytes();
    let n = bytes.len();
    let mut i = 0usize;
    while i < n {
        let c = bytes[i] as char;
        if !(c.is_ascii_alphabetic() || c == '_') {
            i += 1;
            continue;
        }
        let s = i;
        while i < n && is_word(bytes[i] as char) {
            i += 1;
        }
        let name = &code[s..i];
        // skip whitespace between name and `(`
        let mut j = i;
        while j < n && bytes[j] == b' ' {
            j += 1;
        }
        if j >= n || bytes[j] != b'(' {
            continue;
        }
        // `name!(...)` is a macro, not a call
        if i < n && bytes[i] == b'!' {
            continue;
        }
        let prev = if s == 0 { ' ' } else { bytes[s - 1] as char };
        if prev == '.' {
            continue; // method call: pattern-matched, never traversed
        }
        if prev == ':' {
            // qualified: walk back over `<Qual>::`
            if s >= 2 && bytes[s - 2] == b':' {
                if let Some(q) = chain_before(code, s - 2) {
                    let qual = q.rsplit('.').next().unwrap_or(q);
                    if !name.is_empty() && name.chars().next().unwrap().is_ascii_lowercase() || name.starts_with('_') {
                        out.push(Call { qual: Some(qual.to_string()), name: name.to_string(), line });
                    }
                }
            }
            continue;
        }
        if is_word(prev) || prev == '\'' || prev == '"' {
            continue;
        }
        if is_keyword(name) || name.chars().next().unwrap().is_ascii_uppercase() {
            continue;
        }
        out.push(Call { qual: None, name: name.to_string(), line });
    }
}

/// Allow-directive bookkeeping plus the parsed skeleton of one file.
pub struct FileModel {
    pub rel: String,
    pub raw: Vec<String>,
    pub lines: Vec<CleanLine>,
    pub fns: Vec<FnItem>,
    pub depth_start: Vec<usize>,
    site_allow: HashMap<usize, Vec<String>>,
    fn_allow: HashMap<usize, Vec<String>>,
}

/// Parse `analysis: allow(<rule>[, reason])` out of a comment.
fn allow_rule(comment: &str) -> Option<String> {
    let p = comment.find("analysis:")?;
    let rest = comment[p + 9..].trim_start();
    let rest = rest.strip_prefix("allow(")?;
    let end = rest.find(|c| c == ',' || c == ')')?;
    let rule = rest[..end].trim();
    if rule.is_empty() {
        None
    } else {
        Some(rule.to_string())
    }
}

impl FileModel {
    pub fn new(rel: &str, src: &str) -> Self {
        let raw: Vec<String> = src.split('\n').map(|s| s.to_string()).collect();
        let lines = clean_lines(src);
        let (fns, depth_start) = parse_fns(&lines);
        let mut fm = FileModel {
            rel: rel.to_string(),
            raw,
            lines,
            fns,
            depth_start,
            site_allow: HashMap::new(),
            fn_allow: HashMap::new(),
        };
        fm.collect_allows();
        fm
    }

    fn collect_allows(&mut self) {
        let fn_starts: HashMap<usize, ()> = self.fns.iter().map(|f| (f.start, ())).collect();
        for i in 0..self.lines.len() {
            let rule = match allow_rule(&self.lines[i].comment) {
                Some(r) => r,
                None => continue,
            };
            if !self.lines[i].code.trim().is_empty() {
                self.site_allow.entry(i).or_default().push(rule);
                continue;
            }
            // Comment-only directive: applies to the next code line
            // (skipping comments/attributes); if that line starts a fn,
            // the allow is fn-wide and stops rule traversal into it.
            let mut j = i + 1;
            while j < self.lines.len() {
                let cj = self.lines[j].code.trim();
                if !cj.is_empty() && !cj.starts_with("#[") {
                    break;
                }
                j += 1;
            }
            if j < self.lines.len() {
                if fn_starts.contains_key(&j) {
                    self.fn_allow.entry(j).or_default().push(rule);
                } else {
                    self.site_allow.entry(j).or_default().push(rule);
                }
            }
        }
    }

    /// Is `rule` suppressed at `line` (same line or the line above),
    /// or fn-wide for the fn starting at `fn_start`?
    pub fn allowed(&self, rule: &str, line: usize, fn_start: Option<usize>) -> bool {
        let hit = |l: usize| self.site_allow.get(&l).map_or(false, |v| v.iter().any(|r| r == rule));
        if hit(line) || (line > 0 && hit(line - 1)) {
            return true;
        }
        if let Some(s) = fn_start {
            if self.fn_allow.get(&s).map_or(false, |v| v.iter().any(|r| r == rule)) {
                return true;
            }
        }
        false
    }

    /// Fn-wide allow check only (used to prune call-graph traversal).
    pub fn fn_allowed(&self, rule: &str, fn_start: usize) -> bool {
        self.fn_allow.get(&fn_start).map_or(false, |v| v.iter().any(|r| r == rule))
    }
}

/// The whole-crate view: files, flattened non-test fns, their facts,
/// and the name-resolution indices.
pub struct Crate {
    pub files: Vec<FileModel>,
    /// (file index, fn index within that file).
    pub fns: Vec<(usize, usize)>,
    pub facts: Vec<Facts>,
    by_name_free: HashMap<String, Vec<usize>>,
    by_impl: HashMap<(String, String), Vec<usize>>,
    by_file_free: HashMap<(String, String), Vec<usize>>,
}

/// File stem of a path: `src/sched/ws.rs` -> `ws`.
fn stem(rel: &str) -> String {
    let base = rel.rsplit('/').next().unwrap_or(rel);
    base.strip_suffix(".rs").unwrap_or(base).to_string()
}

impl Crate {
    pub fn build(files: Vec<FileModel>) -> Self {
        let mut c = Crate {
            files,
            fns: Vec::new(),
            facts: Vec::new(),
            by_name_free: HashMap::new(),
            by_impl: HashMap::new(),
            by_file_free: HashMap::new(),
        };
        for fi in 0..c.files.len() {
            for gi in 0..c.files[fi].fns.len() {
                if c.files[fi].fns[gi].is_test {
                    continue;
                }
                let fx = extract_facts(&c.files[fi].lines, &c.files[fi].fns[gi]);
                let id = c.fns.len();
                c.fns.push((fi, gi));
                c.facts.push(fx);
                let name = c.files[fi].fns[gi].name.clone();
                let impl_type = c.files[fi].fns[gi].impl_type.clone();
                let file_stem = stem(&c.files[fi].rel);
                match impl_type {
                    Some(t) => c.by_impl.entry((t, name)).or_default().push(id),
                    None => {
                        c.by_name_free.entry(name.clone()).or_default().push(id);
                        c.by_file_free.entry((file_stem, name)).or_default().push(id);
                    }
                }
            }
        }
        c
    }

    pub fn file_of(&self, id: usize) -> &FileModel {
        &self.files[self.fns[id].0]
    }

    pub fn item_of(&self, id: usize) -> &FnItem {
        let (fi, gi) = self.fns[id];
        &self.files[fi].fns[gi]
    }

    /// Resolve a call site to candidate fn ids. Bare names prefer
    /// same-file free fns; `mod::name(` falls back to free fns in
    /// `mod.rs`; `Type::name(` hits that impl's methods; `Self::name(`
    /// uses the caller's impl type. Unresolvable calls return empty.
    pub fn resolve(&self, caller: usize, call: &Call) -> Vec<usize> {
        let fm = self.file_of(caller);
        match &call.qual {
            Some(q) => {
                let q = if q == "Self" {
                    match &self.item_of(caller).impl_type {
                        Some(t) => t.clone(),
                        None => return Vec::new(),
                    }
                } else {
                    q.clone()
                };
                if let Some(v) = self.by_impl.get(&(q.clone(), call.name.clone())) {
                    return v.clone();
                }
                self.by_file_free.get(&(q, call.name.clone())).cloned().unwrap_or_default()
            }
            None => {
                let all = match self.by_name_free.get(&call.name) {
                    Some(v) => v,
                    None => return Vec::new(),
                };
                let same: Vec<usize> =
                    all.iter().copied().filter(|&k| self.fns[k].0 == self.fns[caller].0).collect();
                if same.is_empty() {
                    all.clone()
                } else {
                    same
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_vs_temporary() {
        assert_eq!(guard_binding("        let mut q = self.shared.queue.lock().unwrap();"), Some("q".into()));
        assert_eq!(guard_binding("        let real = mx.lock().expect(      );"), Some("real".into()));
        assert_eq!(guard_binding("        let recs = self.records.lock().unwrap().clone();"), None);
        assert_eq!(guard_binding("        *self.report.lock().unwrap() = info;"), None);
    }

    #[test]
    fn call_scan_classifies() {
        let mut out = Vec::new();
        scan_calls("        claim(Some(tid)); policy::guided_chunk(n, p, 1); x.take(3); Foo::new()", 0, &mut out);
        let names: Vec<(Option<&str>, &str)> =
            out.iter().map(|c| (c.qual.as_deref(), c.name.as_str())).collect();
        assert!(names.contains(&(None, "claim")));
        assert!(names.contains(&(Some("policy"), "guided_chunk")));
        assert!(!names.iter().any(|(_, n)| *n == "take" || *n == "new" || *n == "Some"));
    }

    #[test]
    fn allow_directive_parses() {
        assert_eq!(allow_rule(" analysis: allow(claim-blocking, reason text)"), Some("claim-blocking".into()));
        assert_eq!(allow_rule(" analysis: allow(lock-order)"), Some("lock-order".into()));
        assert_eq!(allow_rule(" nothing here"), None);
    }
}
