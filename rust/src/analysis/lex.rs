//! Line-oriented lexical cleaner for the static analyzer.
//!
//! Produces, for every source line, the *code* portion with string,
//! byte-string, raw-string and char literals blanked out (replaced by
//! spaces, so later pattern scans can't match inside literal text)
//! and block comments erased, plus the text of any `//` line comment.
//! State (open block comments, multi-line strings) carries across
//! lines, so the caller feeds whole files in order.

/// One cleaned source line.
pub struct CleanLine {
    /// Code with literals/comments blanked.
    pub code: String,
    /// Text after a `//` line comment, if any ("" otherwise).
    pub comment: String,
}

#[derive(Clone, Copy)]
enum StrState {
    None,
    /// Inside a normal (or byte) string literal.
    Str,
    /// Inside a raw string; payload is the `#` count of its fence.
    Raw(usize),
}

/// True when `c` can be part of an identifier.
pub fn is_word(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Clean a whole file. Always returns one entry per input line.
pub fn clean_lines(src: &str) -> Vec<CleanLine> {
    let mut out = Vec::new();
    let mut block_depth = 0usize;
    let mut sstate = StrState::None;
    for line in src.split('\n') {
        let ch: Vec<char> = line.chars().collect();
        let n = ch.len();
        let mut code = String::new();
        let mut comment = String::new();
        let mut i = 0usize;
        while i < n {
            if block_depth > 0 {
                if ch[i] == '*' && i + 1 < n && ch[i + 1] == '/' {
                    block_depth -= 1;
                    code.push_str("  ");
                    i += 2;
                } else if ch[i] == '/' && i + 1 < n && ch[i + 1] == '*' {
                    block_depth += 1;
                    code.push_str("  ");
                    i += 2;
                } else {
                    code.push(' ');
                    i += 1;
                }
                continue;
            }
            if let StrState::Raw(h) = sstate {
                if ch[i] == '"' && (1..=h).all(|k| i + k < n && ch[i + k] == '#') {
                    sstate = StrState::None;
                    for _ in 0..=h {
                        code.push(' ');
                    }
                    i += 1 + h;
                } else {
                    code.push(' ');
                    i += 1;
                }
                continue;
            }
            if let StrState::Str = sstate {
                if ch[i] == '\\' {
                    code.push_str("  ");
                    i += 2;
                } else if ch[i] == '"' {
                    sstate = StrState::None;
                    code.push(' ');
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
                continue;
            }
            let c = ch[i];
            if c == '/' && i + 1 < n && ch[i + 1] == '/' {
                comment = ch[i + 2..].iter().collect();
                break;
            }
            if c == '/' && i + 1 < n && ch[i + 1] == '*' {
                block_depth += 1;
                code.push_str("  ");
                i += 2;
                continue;
            }
            // Raw/byte string prefixes (`r"`, `r#"`, `b"`, `br"`) —
            // only when the prefix letter is not part of an identifier.
            if (c == 'r' || c == 'b') && (i == 0 || !is_word(ch[i - 1])) {
                let mut j = i;
                if ch[j] == 'b' {
                    j += 1;
                }
                if j < n && ch[j] == 'r' {
                    j += 1;
                    let mut h = 0usize;
                    while j < n && ch[j] == '#' {
                        j += 1;
                        h += 1;
                    }
                    if j < n && ch[j] == '"' {
                        sstate = StrState::Raw(h);
                        for _ in i..=j {
                            code.push(' ');
                        }
                        i = j + 1;
                        continue;
                    }
                } else if j < n && ch[j] == '"' {
                    sstate = StrState::Str;
                    for _ in i..=j {
                        code.push(' ');
                    }
                    i = j + 1;
                    continue;
                }
                code.push(c);
                i += 1;
                continue;
            }
            if c == '"' {
                sstate = StrState::Str;
                code.push(' ');
                i += 1;
                continue;
            }
            if c == '\'' {
                // Char literal vs lifetime.
                if i + 1 < n && ch[i + 1] == '\\' {
                    let mut j = i + 2;
                    while j < n && ch[j] != '\'' {
                        j += if ch[j] == '\\' { 2 } else { 1 };
                    }
                    let end = j.min(n.saturating_sub(1));
                    for _ in i..=end {
                        code.push(' ');
                    }
                    i = end + 1;
                } else if i + 2 < n && ch[i + 2] == '\'' {
                    code.push_str("   ");
                    i += 3;
                } else {
                    // Lifetime marker: keep, it can't confuse scans.
                    code.push(c);
                    i += 1;
                }
                continue;
            }
            code.push(c);
            i += 1;
        }
        out.push(CleanLine { code, comment });
    }
    out
}

/// Naive substring find over ASCII patterns, returning char index.
pub fn find_from(hay: &str, pat: &str, from: usize) -> Option<usize> {
    if from > hay.len() {
        return None;
    }
    hay[from..].find(pat).map(|p| from + p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_blank_out() {
        let src = "let x = \"a.lock()\"; // order: hi\nlet y = 'c'; /* m.lock() */ z";
        let v = clean_lines(src);
        assert!(!v[0].code.contains("lock"));
        assert_eq!(v[0].comment.trim(), "order: hi");
        assert!(!v[1].code.contains("lock"));
        assert!(v[1].code.contains('z'));
    }

    #[test]
    fn raw_strings_and_lifetimes() {
        let src = "let r = r#\"x.lock()\"#; fn f<'a>(v: &'a str) {}";
        let v = clean_lines(src);
        assert!(!v[0].code.contains("lock"));
        assert!(v[0].code.contains("fn f<'a>"));
    }

    #[test]
    fn block_comment_spans_lines() {
        let v = clean_lines("a /* x\n.lock()\n*/ b");
        assert!(v[1].code.trim().is_empty());
        assert!(v[2].code.contains('b'));
    }
}
