//! Item-level parser: function extraction with `impl` type and
//! `mod tests` region tracking, plus per-line brace depth — the
//! skeleton every rule hangs its per-function facts on.

use super::lex::{is_word, CleanLine};

/// One `fn` item with its body span (line indices, 0-based, inclusive).
pub struct FnItem {
    pub name: String,
    /// Enclosing `impl` type, if any (`impl Foo` / `impl Tr for Foo`).
    pub impl_type: Option<String>,
    pub start: usize,
    pub end: usize,
    /// Inside a `mod tests` block — excluded from the concurrency rules.
    pub is_test: bool,
}

impl FnItem {
    /// `Type::name` or `name`, for diagnostics.
    pub fn qual_name(&self) -> String {
        match &self.impl_type {
            Some(t) => format!("{}::{}", t, self.name),
            None => self.name.clone(),
        }
    }
}

/// Leading identifier of `s` (longest `[A-Za-z_][A-Za-z0-9_]*` prefix).
fn lead_ident(s: &str) -> Option<&str> {
    let bytes = s.as_bytes();
    if bytes.is_empty() || bytes[0].is_ascii_digit() {
        return None;
    }
    let mut k = 0;
    while k < bytes.len() && is_word(bytes[k] as char) {
        k += 1;
    }
    if k == 0 {
        None
    } else {
        Some(&s[..k])
    }
}

/// Does the trimmed line start an `impl` item?
fn is_impl_line(code: &str) -> bool {
    let mut t = code.trim_start();
    for prefix in ["pub ", "unsafe "] {
        if let Some(rest) = t.strip_prefix(prefix) {
            t = rest.trim_start();
        }
    }
    t == "impl" || (t.starts_with("impl") && matches!(t.as_bytes().get(4), Some(&b' ') | Some(&b'<')))
}

/// Does the trimmed line open a `mod tests {` block?
fn is_mod_tests(code: &str) -> bool {
    let t = code.trim_start();
    let t = t.strip_prefix("pub ").unwrap_or(t);
    t.starts_with("mod tests") && t.contains('{')
}

/// Strip generic arguments and path prefix from a type spelling:
/// `map::Wrapper<T>` -> `Wrapper`.
fn strip_generics(s: &str) -> String {
    let mut depth = 0usize;
    let mut out = String::new();
    for c in s.trim().chars() {
        match c {
            '<' => depth += 1,
            '>' => depth = depth.saturating_sub(1),
            _ if depth == 0 => out.push(c),
            _ => {}
        }
    }
    let out = out.trim();
    match out.rfind("::") {
        Some(p) => out[p + 2..].trim().to_string(),
        None => out.to_string(),
    }
}

/// Extract the implementing type name from an `impl ...` line.
fn impl_type_of(code: &str) -> String {
    let p = code.find("impl").unwrap_or(0);
    let mut s = &code[p + 4..];
    // Skip the impl's own generic parameter list.
    let st = s.trim_start();
    if st.starts_with('<') {
        let mut depth = 0usize;
        for (k, c) in s.char_indices() {
            match c {
                '<' => depth += 1,
                '>' => {
                    depth -= 1;
                    if depth == 0 {
                        s = &s[k + 1..];
                        break;
                    }
                }
                _ => {}
            }
        }
    }
    if let Some(p) = s.find(" for ") {
        s = &s[p + 5..];
    }
    for stop in ["{", " where"] {
        if let Some(p) = s.find(stop) {
            s = &s[..p];
        }
    }
    strip_generics(s)
}

/// Find `fn <name>` on a cleaned line; returns the name.
fn fn_name_on(code: &str) -> Option<String> {
    let bytes = code.as_bytes();
    let mut from = 0usize;
    while let Some(p) = code[from..].find("fn ").map(|p| p + from) {
        from = p + 3;
        if p > 0 && is_word(bytes[p - 1] as char) {
            continue; // part of another identifier
        }
        let rest = code[p + 3..].trim_start();
        if let Some(name) = lead_ident(rest) {
            let tail = rest[name.len()..].trim_start();
            if tail.starts_with('(') || tail.starts_with('<') {
                return Some(name.to_string());
            }
        }
    }
    None
}

/// Parse every fn in a cleaned file. Returns the items plus each
/// line's brace depth at line start.
pub fn parse_fns(lines: &[CleanLine]) -> (Vec<FnItem>, Vec<usize>) {
    let mut fns: Vec<FnItem> = Vec::new();
    let mut depth_start = Vec::with_capacity(lines.len());
    let mut depth = 0usize;
    let mut impl_stack: Vec<(String, usize)> = Vec::new();
    let mut test_stack: Vec<usize> = Vec::new();
    let mut pending_impl: Option<String> = None;
    // (name, impl_type, is_test, start_line)
    let mut pending_fn: Option<(String, Option<String>, bool, usize)> = None;
    // (index into fns, depth at body open)
    let mut open_fns: Vec<(usize, usize)> = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        depth_start.push(depth);
        let code = line.code.as_str();
        if is_impl_line(code) {
            if code.contains('{') {
                impl_stack.push((impl_type_of(code), depth));
            } else {
                pending_impl = Some(impl_type_of(code));
            }
        } else if pending_impl.is_some() && code.contains('{') {
            impl_stack.push((pending_impl.take().unwrap(), depth));
        }
        if is_mod_tests(code) {
            test_stack.push(depth);
        }
        if pending_fn.is_none() {
            if let Some(name) = fn_name_on(code) {
                let impl_type = impl_stack.last().map(|(t, _)| t.clone());
                pending_fn = Some((name, impl_type, !test_stack.is_empty(), i));
            }
        }
        for c in code.chars() {
            if c == '{' {
                if let Some((name, impl_type, is_test, start)) = pending_fn.take() {
                    fns.push(FnItem { name, impl_type, start, end: i, is_test });
                    open_fns.push((fns.len() - 1, depth));
                }
                depth += 1;
            } else if c == '}' {
                depth = depth.saturating_sub(1);
                while let Some(&(fi, d)) = open_fns.last() {
                    if depth == d {
                        fns[fi].end = i;
                        open_fns.pop();
                    } else {
                        break;
                    }
                }
                while let Some(&(_, d)) = impl_stack.last() {
                    if depth == d {
                        impl_stack.pop();
                    } else {
                        break;
                    }
                }
                while let Some(&d) = test_stack.last() {
                    if depth == d {
                        test_stack.pop();
                    } else {
                        break;
                    }
                }
            }
        }
        if pending_fn.is_some() && code.contains(';') {
            pending_fn = None; // bodyless trait-method declaration
        }
    }
    let last = lines.len().saturating_sub(1);
    for (fi, _) in open_fns {
        fns[fi].end = last;
    }
    (fns, depth_start)
}

#[cfg(test)]
mod parser_tests {
    use super::super::lex::clean_lines;
    use super::*;

    #[test]
    fn impl_and_free_fns() {
        let src = "impl Foo {\n    pub fn a(&self) {\n    }\n}\nfn b() {\n}\n";
        let (fns, _) = parse_fns(&clean_lines(src));
        assert_eq!(fns.len(), 2);
        assert_eq!(fns[0].qual_name(), "Foo::a");
        assert_eq!((fns[0].start, fns[0].end), (1, 2));
        assert_eq!(fns[1].qual_name(), "b");
        assert!(!fns[0].is_test);
    }

    #[test]
    fn trait_impl_and_tests_mod() {
        let src = "impl fmt::Debug for Bar<T> {\n    fn fmt(&self) {}\n}\nmod tests {\n    fn t() {}\n}\n";
        let (fns, _) = parse_fns(&clean_lines(src));
        assert_eq!(fns[0].impl_type.as_deref(), Some("Bar"));
        assert!(fns[1].is_test);
    }

    #[test]
    fn bodyless_decl_is_skipped() {
        let src = "trait T {\n    fn decl(&self);\n    fn has(&self) {}\n}\n";
        let (fns, _) = parse_fns(&clean_lines(src));
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].name, "has");
    }
}
