//! Graph analytics driver: level-synchronous BFS over uniform and
//! scale-free graphs (the paper's Fig 5a experiment), run both on the
//! simulated testbed and for real, and showing the headline claim that
//! iCh's adaptive chunk improves the plain-stealing base algorithm.
//!
//! ```text
//! cargo run --release --example graph_bfs [-- --vertices 50000]
//! ```

use ich::apps::bfs::Bfs;
use ich::apps::App;
use ich::harness::speedup::{best_time, sim_time};
use ich::sched::{IchParams, Policy};
use ich::sim::MachineSpec;
use ich::util::cli::Args;
use ich::util::table::{f2, Table};

fn main() {
    let args = Args::from_env(&[]);
    let n = args.get_usize("vertices", 50_000);
    let spec = MachineSpec::default();

    for (label, app) in [
        ("uniform", Bfs::uniform(n, 16, 1)),
        ("scale-free", Bfs::scale_free(n, 2_000, 2.3, 1)),
    ] {
        let loops = app.sim_loops();
        println!(
            "# BFS ({label}): {} vertices, {} levels, {} frontier iterations",
            n,
            loops.len(),
            loops.iter().map(|l| l.weights.len()).sum::<usize>()
        );

        // Simulated speedups @28: the paper's iCh-vs-stealing claim.
        let t_ref = best_time(&spec, &loops, "guided", 1, 5);
        let mut t = Table::new(["policy", "sim speedup@28"]);
        let mut ich28 = 0.0;
        let mut steal28 = 0.0;
        for pol in [
            Policy::Guided { chunk: 1 },
            Policy::Dynamic { chunk: 1 },
            Policy::Taskloop { num_tasks: 0 },
            Policy::Binlpt { max_chunks: 384 },
            Policy::Stealing { chunk: 1 },
            Policy::Ich(IchParams::with_eps(0.33)),
        ] {
            let sp = t_ref / sim_time(&spec, &loops, &pol, 28, 5);
            if matches!(pol, Policy::Ich(_)) {
                ich28 = sp;
            }
            if matches!(pol, Policy::Stealing { .. }) {
                steal28 = sp;
            }
            t.row([pol.name(), f2(sp)]);
        }
        println!("{}", t.render());
        println!(
            "iCh vs plain stealing @28: {:+.1}% (paper: +9.6% uniform, +54% scale-free)\n",
            100.0 * (ich28 - steal28) / steal28
        );

        // Real run: correctness of the parallel traversal.
        let r = app.run_real(&Policy::Ich(IchParams::default()), 4, 9);
        println!(
            "real run (4 threads): {:.4}s valid={} chunks={} steals={}ok/{}fail\n",
            r.elapsed_s, r.valid, r.metrics.total_chunks, r.metrics.steals_ok, r.metrics.steals_failed
        );
        assert!(r.valid, "parallel BFS must match the sequential reference");
    }
}
