//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! L3 (Rust iCh scheduler) hands out iteration chunks; each chunk's
//! compute executes through the L2/L1 AOT artifacts (JAX + Pallas →
//! HLO text → PJRT CPU) loaded by `runtime::Kernels`. Python is not
//! involved at any point in this binary — run `make artifacts` first.
//!
//! Workloads (all validated against pure-Rust sequential references):
//!   1. K-Means over a KDD-like mixture — assignment via the
//!      `kmeans_assign` Pallas kernel, scheduled by iCh.
//!   2. SpMV over a circuit-like matrix — row blocks via the
//!      `spmv_ell` Pallas kernel, scheduled by iCh.
//!   3. LavaMD 4×4×4 — per-box forces via the `lavamd_force` kernel.
//!
//! Finally it prints the paper's headline metric on the simulated
//! testbed (iCh top-3 / gap-to-best per app) and records everything in
//! results/e2e.json. ```cargo run --release --example e2e_paper_run```

use std::sync::atomic::{AtomicU32, Ordering::Relaxed};

use ich::apps;
use ich::harness::speedup::curves;
use ich::runtime::service::KernelService;
use ich::sched::{parallel_for, ForOpts, IchParams, Policy, PAPER_FAMILIES};
use ich::sim::MachineSpec;
use ich::sparse::gen;
use ich::util::json::Json;
use ich::util::rng::Rng;
use ich::util::table::{f2, Table};

fn main() {
    let Some(service) = KernelService::spawn() else {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(2);
    };
    let kernels = service.handle();
    let policy = Policy::Ich(IchParams::with_eps(0.33));
    let threads = 4;
    let mut report = Json::obj();

    // ---------------------------------------------------------------
    // 1. K-Means: L3 iCh schedules point blocks; L1 Pallas kernel
    //    (via PJRT) computes each block's assignments.
    // ---------------------------------------------------------------
    println!("== [1/3] K-Means assignment through the kmeans_assign artifact ==");
    let (n, d, k) = (8_192usize, 34usize, 5usize);
    let mut rng = Rng::new(0xE2E);
    let centers: Vec<f32> = (0..k * d).map(|_| (rng.next_f64() * 10.0) as f32).collect();
    let points: Vec<f32> = (0..n)
        .flat_map(|i| {
            let c = i % k;
            (0..d).map(move |f| (c * d + f, i)).collect::<Vec<_>>()
        })
        .map(|(ci, _)| centers[ci % (k * d)])
        .zip((0..n * d).map(|_| rng.normal(0.0, 0.5) as f32))
        .map(|(c, eps)| c + eps)
        .collect();

    // Sequential Rust reference.
    let reference: Vec<u32> = (0..n)
        .map(|i| {
            let p = &points[i * d..(i + 1) * d];
            (0..k)
                .min_by(|&a, &b| {
                    let da: f32 = p.iter().zip(&centers[a * d..(a + 1) * d]).map(|(x, c)| (x - c) * (x - c)).sum();
                    let db: f32 = p.iter().zip(&centers[b * d..(b + 1) * d]).map(|(x, c)| (x - c) * (x - c)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap() as u32
        })
        .collect();

    let assign: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(u32::MAX)).collect();
    let start = std::time::Instant::now();
    let m = parallel_for(n, &policy, &ForOpts::threads(threads), &|r| {
        let got = kernels.kmeans_assign(&points[r.start * d..r.end * d], d, &centers, k).unwrap();
        for (i, a) in r.zip(got) {
            assign[i].store(a, Relaxed);
        }
    });
    let kmeans_s = start.elapsed().as_secs_f64();
    let got: Vec<u32> = assign.iter().map(|a| a.load(Relaxed)).collect();
    let agree = got.iter().zip(&reference).filter(|(a, b)| a == b).count();
    println!(
        "  {n} points, {k} clusters: {:.3}s, {} chunks, {} steals, agreement {}/{}",
        kmeans_s, m.total_chunks, m.steals_ok, agree, n
    );
    assert!(agree as f64 >= 0.999 * n as f64, "kernel assignments must match the Rust reference");

    // ---------------------------------------------------------------
    // 2. SpMV: iCh schedules row ranges; spmv_ell artifact executes.
    // ---------------------------------------------------------------
    println!("== [2/3] SpMV through the spmv_ell artifact ==");
    let a = gen::regular_random(4_096, 8, 3, 0xE2E2);
    let x: Vec<f32> = (0..a.ncols).map(|i| ((i % 13) as f32 - 6.0) / 5.0).collect();
    let mut want = vec![0.0f32; a.nrows];
    a.spmv_seq(&x, &mut want);
    let y: Vec<AtomicU32> = (0..a.nrows).map(|_| AtomicU32::new(0)).collect();
    let start = std::time::Instant::now();
    let m = parallel_for(a.nrows, &policy, &ForOpts::threads(threads), &|r| {
        let got = kernels.spmv_rows(&a, &x, r.clone()).unwrap();
        for (row, v) in r.zip(got) {
            y[row].store(v.to_bits(), Relaxed);
        }
    });
    let spmv_s = start.elapsed().as_secs_f64();
    let maxerr = (0..a.nrows)
        .map(|r| (f32::from_bits(y[r].load(Relaxed)) - want[r]).abs() / want[r].abs().max(1.0))
        .fold(0.0f32, f32::max);
    println!(
        "  {} rows ({} nnz): {:.3}s, {} chunks, {} steals, max rel err {:.2e}",
        a.nrows,
        a.nnz(),
        spmv_s,
        m.total_chunks,
        m.steals_ok,
        maxerr
    );
    assert!(maxerr < 1e-3, "kernel SpMV must match the Rust reference");

    // ---------------------------------------------------------------
    // 3. LavaMD: per-box forces through the lavamd_force artifact.
    // ---------------------------------------------------------------
    println!("== [3/3] LavaMD forces through the lavamd_force artifact ==");
    let side = 4usize;
    let nboxes = side * side * side;
    let mut rng = Rng::new(0xE2E3);
    let boxes: Vec<Vec<[f32; 4]>> = (0..nboxes)
        .map(|b| {
            let (bi, bj, bk) = (b / (side * side), (b / side) % side, b % side);
            (0..rng.range(16, 48))
                .map(|_| {
                    [
                        bi as f32 + rng.next_f64() as f32,
                        bj as f32 + rng.next_f64() as f32,
                        bk as f32 + rng.next_f64() as f32,
                        rng.next_f64() as f32 - 0.5,
                    ]
                })
                .collect()
        })
        .collect();
    let neighborhood = |b: usize| -> Vec<[f32; 4]> {
        let (bi, bj, bk) = ((b / (side * side)) as isize, ((b / side) % side) as isize, (b % side) as isize);
        let mut out = Vec::new();
        for di in -1..=1isize {
            for dj in -1..=1isize {
                for dk in -1..=1isize {
                    let (i, j, kk) = (bi + di, bj + dj, bk + dk);
                    if (0..side as isize).contains(&i) && (0..side as isize).contains(&j) && (0..side as isize).contains(&kk) {
                        out.extend(&boxes[(i as usize * side + j as usize) * side + kk as usize]);
                    }
                }
            }
        }
        out
    };
    // Sequential Rust reference (same math as apps::lavamd).
    let reference: Vec<f32> = (0..nboxes)
        .map(|b| {
            let nb = neighborhood(b);
            boxes[b]
                .iter()
                .map(|p| {
                    nb.iter()
                        .map(|q| {
                            let (dx, dy, dz) = (p[0] - q[0], p[1] - q[1], p[2] - q[2]);
                            let r2 = dx * dx + dy * dy + dz * dz;
                            if r2 > 0.0 && r2 < 1.0 { p[3] * q[3] * (-r2).exp() / (r2 + 0.05) } else { 0.0 }
                        })
                        .sum::<f32>()
                })
                .sum()
        })
        .collect();
    let forces: Vec<AtomicU32> = (0..nboxes).map(|_| AtomicU32::new(0)).collect();
    let start = std::time::Instant::now();
    let m = parallel_for(nboxes, &policy, &ForOpts::threads(threads), &|r| {
        for b in r {
            let f = kernels.lavamd_force(&boxes[b], &neighborhood(b)).unwrap();
            forces[b].store(f.iter().sum::<f32>().to_bits(), Relaxed);
        }
    });
    let lavamd_s = start.elapsed().as_secs_f64();
    let maxerr = (0..nboxes)
        .map(|b| (f32::from_bits(forces[b].load(Relaxed)) - reference[b]).abs() / reference[b].abs().max(1.0))
        .fold(0.0f32, f32::max);
    println!("  {nboxes} boxes: {:.3}s, {} chunks, max rel err {:.2e}", lavamd_s, m.total_chunks, maxerr);
    assert!(maxerr < 1e-2, "kernel forces must match the Rust reference");

    // ---------------------------------------------------------------
    // Headline metric on the simulated testbed (paper §6.1 insight).
    // ---------------------------------------------------------------
    println!("\n== headline: iCh rank / gap-to-best per application (28 simulated threads) ==");
    let spec = MachineSpec::default();
    let mut t = Table::new(["app", "ich@28", "best@28", "rank", "gap"]);
    let mut gaps = Vec::new();
    let mut apps_json = Json::obj();
    for name in apps::APP_NAMES {
        let app = apps::make_app(name, 0x1C41C4).unwrap();
        let c = curves(&spec, app.as_ref(), PAPER_FAMILIES, ich::harness::speedup::THREADS, 0x1C41C4);
        let best = c.series.iter().map(|(_, v)| *v.last().unwrap()).fold(0.0, f64::max);
        let gap = c.gap_to_best("ich");
        gaps.push(gap);
        t.row([
            c.app.clone(),
            f2(c.at_max("ich")),
            f2(best),
            c.rank_at_max("ich").to_string(),
            format!("{:.1}%", gap * 100.0),
        ]);
        let mut o = Json::obj();
        o.set("rank", Json::num(c.rank_at_max("ich") as f64));
        o.set("gap", Json::num(gap));
        apps_json.set(name, o);
    }
    println!("{}", t.render());
    let avg = ich::util::stats::mean(&gaps);
    println!("average gap to best: {:.1}%  (paper: ~5.4%)", avg * 100.0);

    report.set("kmeans_s", Json::num(kmeans_s));
    report.set("spmv_s", Json::num(spmv_s));
    report.set("lavamd_s", Json::num(lavamd_s));
    report.set("avg_gap", Json::num(avg));
    report.set("apps", apps_json);
    report.save("results/e2e.json").unwrap();
    println!("\nwrote results/e2e.json — all three layers composed: Rust iCh scheduler → PJRT → Pallas kernels ✔");
}
