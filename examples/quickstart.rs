//! Quickstart: schedule an irregular loop with iCh in five lines.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use ich::{parallel_for, ForOpts, IchParams, Policy};
use std::sync::atomic::{AtomicU64, Ordering};

fn main() {
    // An irregular workload: iteration i costs ~i work units.
    let n = 200_000;
    let acc = AtomicU64::new(0);

    // Schedule it with iCh (ε = 33%) over 4 worker threads.
    let policy = Policy::Ich(IchParams::with_eps(0.33));
    let opts = ForOpts::threads(4);
    let metrics = parallel_for(n, &policy, &opts, &|range| {
        let mut local = 0u64;
        for i in range {
            // irregular per-iteration work
            let mut x = i as u64;
            for _ in 0..(i % 97) {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            }
            local = local.wrapping_add(x);
        }
        acc.fetch_add(local, Ordering::Relaxed);
    });

    println!("iterations executed : {}", metrics.total_iters);
    println!("chunks dispatched   : {}", metrics.total_chunks);
    println!("mean chunk size     : {:.1}", metrics.mean_chunk());
    println!("steals (ok/fail)    : {}/{}", metrics.steals_ok, metrics.steals_failed);
    println!("imbalance (max/mean): {:.3}", metrics.imbalance());
    println!("elapsed             : {:.3}s", metrics.elapsed_s);
    println!("checksum            : {}", acc.load(Ordering::Relaxed));
    assert_eq!(metrics.total_iters, n as u64);

    // Swap policies without touching the loop body:
    for sched in ["guided,1", "dynamic,2", "stealing,2", "binlpt,128"] {
        let p = Policy::parse(sched).unwrap();
        let m = parallel_for(n, &p, &opts, &|range| {
            std::hint::black_box(range.len());
        });
        println!("{:>12}: {} chunks", p.name(), m.total_chunks);
    }
}
