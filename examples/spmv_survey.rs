//! SpMV scheduling survey: run y = A·x over the Table-1 synthetic
//! suite under every paper scheduler, on the simulated 28-thread
//! testbed AND for real on this machine — the paper's §6.1 SpMV
//! experiment end to end, with the variance-vs-iCh insight check.
//!
//! ```text
//! cargo run --release --example spmv_survey [-- --rows 4000]
//! ```

use ich::apps::spmv::Spmv;
use ich::apps::App;
use ich::harness::speedup::{best_time, THREADS};
use ich::sched::{IchParams, Policy, PAPER_FAMILIES};
use ich::sim::MachineSpec;
use ich::sparse::{stats, suite};
use ich::util::cli::Args;
use ich::util::stats::geomean;
use ich::util::table::{compact, f2, Table};

fn main() {
    let args = Args::from_env(&[]);
    let rows = args.get_usize("rows", 4_000);
    let spec = MachineSpec::default();
    let p = *THREADS.last().unwrap();

    let mut t = Table::new(["input", "σ²", "best", "ich speedup", "best speedup", "ich rank"]);
    let mut ich_by_var: Vec<(bool, f64)> = Vec::new(); // (high_variance, gap)
    let mut per_family: Vec<Vec<f64>> = vec![Vec::new(); PAPER_FAMILIES.len()];

    for e in suite::table1() {
        let a = e.generate(rows);
        let s = stats::row_stats(&a);
        let app = Spmv::new(e.name, a);
        let loops = app.sim_loops();
        let t_ref = best_time(&spec, &loops, "guided", 1, 7);
        let sp: Vec<(String, f64)> = PAPER_FAMILIES
            .iter()
            .map(|fam| (fam.to_string(), t_ref / best_time(&spec, &loops, fam, p, 7)))
            .collect();
        for (fi, (_f, v)) in sp.iter().enumerate() {
            per_family[fi].push(*v);
        }
        let (best_fam, best) =
            sp.iter().max_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).map(|(f, v)| (f.clone(), *v)).unwrap();
        let ich = sp.iter().find(|(f, _)| f == "ich").unwrap().1;
        let rank = 1 + sp.iter().filter(|(_, v)| *v > ich).count();
        ich_by_var.push((stats::high_variance(&s), (best - ich) / best));
        t.row([
            e.name.to_string(),
            compact(s.variance),
            best_fam,
            f2(ich),
            f2(best),
            rank.to_string(),
        ]);
    }
    println!("# SpMV survey over the Table-1 suite ({} rows each, {} simulated threads)\n{}", rows, p, t.render());

    let mut g = Table::new(["family", "geomean speedup@28"]);
    for (fi, fam) in PAPER_FAMILIES.iter().enumerate() {
        g.row([fam.to_string(), f2(geomean(&per_family[fi]))]);
    }
    println!("{}", g.render());

    // §6.1 insight: iCh's gap to best should be smaller on
    // high-variance inputs than on low-variance ones.
    let hi: Vec<f64> = ich_by_var.iter().filter(|(h, _)| *h).map(|(_, g)| *g).collect();
    let lo: Vec<f64> = ich_by_var.iter().filter(|(h, _)| !*h).map(|(_, g)| *g).collect();
    println!(
        "iCh mean gap-to-best: high-variance inputs {:.1}% vs low-variance {:.1}% (paper: iCh favors high variance)",
        100.0 * ich::util::stats::mean(&hi),
        100.0 * ich::util::stats::mean(&lo),
    );

    // Real execution sanity on one input: every scheduler must produce
    // the same y (validated inside run_real).
    let e = &suite::table1()[3]; // patents analog
    let app = Spmv::new(e.name, e.generate(rows));
    println!("\n# real runs on this machine ({} threads): {}", 4, app.name());
    for pol in [
        Policy::Guided { chunk: 1 },
        Policy::Dynamic { chunk: 2 },
        Policy::Stealing { chunk: 2 },
        Policy::Ich(IchParams::default()),
    ] {
        let r = app.run_real(&pol, 4, 11);
        println!(
            "  {:>12}: {:.4}s valid={} chunks={} steals={}ok",
            pol.name(),
            r.elapsed_s,
            r.valid,
            r.metrics.total_chunks,
            r.metrics.steals_ok
        );
        assert!(r.valid);
    }
}
