"""Pallas ELL SpMV vs the pure-jnp oracle (ref.spmv_ell)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.spmv_ell import csr_to_ell, spmv_ell


def _case(rng, r, w, n):
    values = rng.standard_normal((r, w)).astype(np.float32)
    # zero-pad a random suffix of each row (the ELL convention)
    pad = rng.integers(0, w + 1, size=r)
    for i in range(r):
        values[i, w - pad[i]:] = 0.0
    cols = rng.integers(0, n, size=(r, w)).astype(np.int32)
    x = rng.standard_normal(n).astype(np.float32)
    return values, cols, x


def test_matches_ref_basic(rng):
    values, cols, x = _case(rng, 256, 8, 100)
    got = spmv_ell(jnp.array(values), jnp.array(cols), jnp.array(x))
    want = ref.spmv_ell(jnp.array(values), jnp.array(cols), jnp.array(x))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_zero_matrix_gives_zero(rng):
    values = np.zeros((128, 4), dtype=np.float32)
    cols = np.zeros((128, 4), dtype=np.int32)
    x = rng.standard_normal(50).astype(np.float32)
    got = spmv_ell(jnp.array(values), jnp.array(cols), jnp.array(x))
    np.testing.assert_array_equal(np.asarray(got), np.zeros(128, np.float32))


def test_identity_rows(rng):
    # values 1 at col i -> y = x[:R]
    r, n = 128, 256
    values = np.zeros((r, 2), dtype=np.float32)
    values[:, 0] = 1.0
    cols = np.zeros((r, 2), dtype=np.int32)
    cols[:, 0] = np.arange(r)
    x = rng.standard_normal(n).astype(np.float32)
    got = np.asarray(spmv_ell(jnp.array(values), jnp.array(cols), jnp.array(x)))
    np.testing.assert_allclose(got, x[:r], rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    rblocks=st.integers(1, 4),
    w=st.integers(1, 24),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**31),
)
def test_matches_ref_hypothesis(rblocks, w, n, seed):
    """Shape sweep: any (R, W, N) with R a multiple of the block."""
    block = 32
    r = rblocks * block
    rng = np.random.default_rng(seed)
    values, cols, x = _case(rng, r, w, n)
    got = spmv_ell(jnp.array(values), jnp.array(cols), jnp.array(x), block_rows=block)
    want = ref.spmv_ell(jnp.array(values), jnp.array(cols), jnp.array(x))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_csr_to_ell_roundtrip():
    rowptr = [0, 2, 2, 5]
    colidx = [1, 3, 0, 2, 4]
    vals = [1.0, 2.0, 3.0, 4.0, 5.0]
    values, cols = csr_to_ell(rowptr, colidx, vals)
    assert values.shape == (3, 3)
    np.testing.assert_array_equal(values[0], [1.0, 2.0, 0.0])
    np.testing.assert_array_equal(cols[0], [1, 3, 0])
    np.testing.assert_array_equal(values[1], [0.0, 0.0, 0.0])
    np.testing.assert_array_equal(values[2], [3.0, 4.0, 5.0])


def test_csr_to_ell_respects_width():
    values, cols = csr_to_ell([0, 3], [0, 1, 2], [1.0, 2.0, 3.0], width=2)
    assert values.shape == (1, 2)  # truncated
