"""Pallas K-Means assignment vs the oracle (ref.kmeans_assign)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.kmeans_assign import kmeans_assign


def test_matches_ref_basic(rng):
    p = rng.standard_normal((512, 34)).astype(np.float32)
    c = rng.standard_normal((16, 34)).astype(np.float32)
    a, d = kmeans_assign(jnp.array(p), jnp.array(c))
    ra, rd = ref.kmeans_assign(jnp.array(p), jnp.array(c))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ra))
    np.testing.assert_allclose(d, rd, rtol=1e-3, atol=1e-3)


def test_points_on_centroids_assign_self(rng):
    c = (rng.standard_normal((8, 16)) * 10).astype(np.float32)
    p = np.repeat(c, 32, axis=0)  # 256 points, exact copies
    a, d = kmeans_assign(jnp.array(p), jnp.array(c), block_points=128)
    want = np.repeat(np.arange(8), 32)
    np.testing.assert_array_equal(np.asarray(a), want.astype(np.int32))
    np.testing.assert_allclose(np.asarray(d), np.zeros(256), atol=1e-3)


def test_single_centroid(rng):
    p = rng.standard_normal((256, 4)).astype(np.float32)
    c = np.zeros((1, 4), dtype=np.float32)
    a, d = kmeans_assign(jnp.array(p), jnp.array(c))
    assert (np.asarray(a) == 0).all()
    np.testing.assert_allclose(np.asarray(d), (p * p).sum(1), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(1, 4),
    d=st.integers(1, 40),
    k=st.integers(1, 20),
    seed=st.integers(0, 2**31),
)
def test_matches_ref_hypothesis(blocks, d, k, seed):
    block = 64
    n = blocks * block
    rng = np.random.default_rng(seed)
    p = rng.standard_normal((n, d)).astype(np.float32)
    c = rng.standard_normal((k, d)).astype(np.float32)
    a, dist = kmeans_assign(jnp.array(p), jnp.array(c), block_points=block)
    ra, rd = ref.kmeans_assign(jnp.array(p), jnp.array(c))
    # Ties can flip argmin between float paths; verify via distances.
    np.testing.assert_allclose(dist, rd, rtol=1e-2, atol=1e-2)
    mismatch = (np.asarray(a) != np.asarray(ra))
    if mismatch.any():
        # every mismatch must be a near-tie
        d_got = np.asarray(dist)[mismatch]
        d_ref = np.asarray(rd)[mismatch]
        np.testing.assert_allclose(d_got, d_ref, rtol=1e-2, atol=1e-2)
