"""AOT pipeline sanity: models lower to HLO text, manifest matches."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.mark.parametrize("name", sorted(model.MODELS))
def test_lowering_produces_hlo_text(name):
    args = model.example_args(name)
    lowered = jax.jit(model.MODELS[name]).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:50]
    assert "ENTRY" in text


@pytest.mark.parametrize("name", sorted(model.MODELS))
def test_models_run_on_example_shapes(name):
    rng = np.random.default_rng(1)
    args = []
    for spec in model.example_args(name):
        if spec.dtype == jnp.int32:
            args.append(jnp.array(rng.integers(0, 4, spec.shape).astype(np.int32)))
        else:
            args.append(jnp.array(rng.standard_normal(spec.shape).astype(np.float32)))
    out = model.MODELS[name](*args)
    assert isinstance(out, tuple) and len(out) >= 1
    for o in out:
        assert np.isfinite(np.asarray(o, dtype=np.float64)).all()


def test_build_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.build(out)
    with open(os.path.join(out, "manifest.json")) as f:
        m = json.load(f)
    assert m["format"] == "hlo-text"
    assert set(m["models"]) == set(model.MODELS)
    for name, entry in m["models"].items():
        assert os.path.exists(os.path.join(out, entry["file"])), name
        assert entry["shapes"] == model.AOT_SHAPES[name]
