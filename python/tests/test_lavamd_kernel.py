"""Pallas LavaMD force kernel vs the oracle (ref.lavamd_force)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.lavamd_force import lavamd_force


def _particles(rng, n, spread=1.0):
    p = rng.standard_normal((n, 4)).astype(np.float32)
    p[:, :3] *= spread
    return p


def test_matches_ref_basic(rng):
    h = _particles(rng, 64)
    g = _particles(rng, 256)
    got = lavamd_force(jnp.array(h), jnp.array(g))
    want = ref.lavamd_force(jnp.array(h), jnp.array(g))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-5)


def test_padded_particles_are_inert(rng):
    h = _particles(rng, 32)
    g = _particles(rng, 64)
    gp = np.vstack([g, np.zeros((16, 4), np.float32)])
    # q=0 pad rows at the origin must contribute nothing
    a = np.asarray(lavamd_force(jnp.array(h), jnp.array(g)))
    b = np.asarray(lavamd_force(jnp.array(h), jnp.array(gp)))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_far_particles_cut_off(rng):
    h = _particles(rng, 16, spread=0.1)
    g = _particles(rng, 32, spread=0.1)
    g[:, :3] += 100.0  # beyond the cutoff
    got = np.asarray(lavamd_force(jnp.array(h), jnp.array(g)))
    np.testing.assert_array_equal(got, np.zeros(16, np.float32))


def test_self_interaction_excluded(rng):
    # identical particle in home and neigh: r2 == 0 slot is skipped
    p = _particles(rng, 8, spread=0.05)
    got = np.asarray(lavamd_force(jnp.array(p), jnp.array(p)))
    want = np.asarray(ref.lavamd_force(jnp.array(p), jnp.array(p)))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 64),
    m=st.integers(1, 128),
    spread=st.floats(0.05, 3.0),
    seed=st.integers(0, 2**31),
)
def test_matches_ref_hypothesis(b, m, spread, seed):
    rng = np.random.default_rng(seed)
    h = _particles(rng, b, spread)
    g = _particles(rng, m, spread)
    got = lavamd_force(jnp.array(h), jnp.array(g))
    want = ref.lavamd_force(jnp.array(h), jnp.array(g))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)
