"""Shared pytest fixtures/helpers for the kernel test suite."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0xD5EED)
