"""L1 Pallas kernel: K-Means nearest-centroid assignment.

TPU mapping: the (BP, D) point block × (K, D) centroid tile distance
matrix is computed via the matmul expansion ||p−c||² = ||p||² − 2p·cᵀ
+ ||c||², so the dominant term is a (BP, D)×(D, K) matmul that lands
on the MXU in f32. The centroid tile is tiny (K×D) and stays resident
in VMEM across the whole grid.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_POINTS = 256


def _kernel(points_ref, cent_ref, assign_ref, dist_ref):
    p = points_ref[...]  # (BP, D)
    c = cent_ref[...]  # (K, D)
    p2 = jnp.sum(p * p, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)[None, :]
    d2 = p2 - 2.0 * (p @ c.T) + c2  # (BP, K) — MXU matmul
    assign_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d2, axis=1)


@functools.partial(jax.jit, static_argnames=("block_points",))
def kmeans_assign(points, centroids, *, block_points=DEFAULT_BLOCK_POINTS):
    """Pallas assignment. points (P, D) with P % block_points == 0
    (pad with copies of point 0), centroids (K, D). Returns
    (assign (P,) i32, dist2 (P,) f32)."""
    p, d = points.shape
    k, d2 = centroids.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    assert p % block_points == 0, f"P={p} must be a multiple of {block_points}"
    grid = (p // block_points,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_points, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),  # centroids resident
        ],
        out_specs=[
            pl.BlockSpec((block_points,), lambda i: (i,)),
            pl.BlockSpec((block_points,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), jnp.int32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
        ],
        interpret=True,
    )(points, centroids)
