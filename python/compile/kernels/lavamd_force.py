"""L1 Pallas kernel: LavaMD per-box force accumulation.

TPU mapping: one grid step processes one box — a (B, 4) home-particle
tile against the (M, 4) concatenated 27-neighborhood tile. The (B, M)
pairwise distance field is built from rank-1 broadcasts (VPU work; the
exp/div transcendentals dominate), with padded particles neutralized
by q = 0 rather than masks on shape, keeping every tile dense and
static. Both tiles fit comfortably in VMEM (B=64, M=1728 → ~450 KiB).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CUTOFF2 = 1.0


def _kernel(home_ref, neigh_ref, out_ref):
    h = home_ref[...]  # (B, 4)
    g = neigh_ref[...]  # (M, 4)
    d = h[:, None, :3] - g[None, :, :3]  # (B, M, 3)
    r2 = jnp.sum(d * d, axis=2)
    qq = h[:, 3][:, None] * g[None, :, 3]
    contrib = qq * jnp.exp(-r2) / (r2 + 0.05)
    mask = (r2 > 0.0) & (r2 < CUTOFF2)
    out_ref[...] = jnp.sum(jnp.where(mask, contrib, 0.0), axis=1)


@functools.partial(jax.jit)
def lavamd_force(home, neigh):
    """Pallas per-box force. home (B, 4), neigh (M, 4), rows are
    (x, y, z, q) with q = 0 padding. Returns (B,) f32."""
    b, four = home.shape
    m, four2 = neigh.shape
    assert four == 4 and four2 == 4, "particles are (x, y, z, q) rows"
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((b, 4), lambda i: (0, 0)),
            pl.BlockSpec((m, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(home, neigh)
