"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this
package is asserted allclose against the function of the same name here
(pytest + hypothesis sweeps in python/tests/).
"""

import jax.numpy as jnp


def spmv_ell(values, cols, x):
    """ELL-format SpMV: y[r] = sum_w values[r, w] * x[cols[r, w]].

    Padding convention: padded slots carry value 0.0 (their column
    index may be anything valid, typically 0).

    Args:
      values: (R, W) f32 -- per-row nonzero values, zero-padded.
      cols:   (R, W) i32 -- per-row column indices.
      x:      (N,)   f32 -- dense input vector.
    Returns:
      (R,) f32.
    """
    return jnp.sum(values * x[cols], axis=1)


def kmeans_assign(points, centroids):
    """Nearest-centroid assignment (+ distance), the K-Means inner loop.

    Distances use the matmul expansion ||p - c||^2 = ||p||^2 - 2 p.c +
    ||c||^2, which maps onto the MXU (the kernel uses the same algebra).

    Args:
      points:    (B, D) f32.
      centroids: (K, D) f32.
    Returns:
      assign: (B,) i32 -- index of the nearest centroid.
      dist2:  (B,) f32 -- squared distance to it.
    """
    p2 = jnp.sum(points * points, axis=1, keepdims=True)  # (B, 1)
    c2 = jnp.sum(centroids * centroids, axis=1)[None, :]  # (1, K)
    d2 = p2 - 2.0 * points @ centroids.T + c2  # (B, K)
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dist2 = jnp.min(d2, axis=1)
    return assign, dist2


def lavamd_force(home, neigh, cutoff2=1.0):
    """Screened-Coulomb force accumulation for one LavaMD box.

    Particles are rows (x, y, z, q); padded rows use q = 0 so they
    contribute nothing. Interactions beyond `cutoff2` (squared cutoff)
    or at zero distance are excluded -- matching the Rust reference
    implementation in rust/src/apps/lavamd.rs.

    Args:
      home:  (B, 4) f32 -- the box's own particles.
      neigh: (M, 4) f32 -- all particles of the 27-neighborhood.
    Returns:
      (B,) f32 -- per-home-particle force accumulation.
    """
    d = home[:, None, :3] - neigh[None, :, :3]  # (B, M, 3)
    r2 = jnp.sum(d * d, axis=2)  # (B, M)
    qq = home[:, 3][:, None] * neigh[None, :, 3]
    contrib = qq * jnp.exp(-r2) / (r2 + 0.05)
    mask = (r2 > 0.0) & (r2 < cutoff2)
    return jnp.sum(jnp.where(mask, contrib, 0.0), axis=1)
