"""L1 Pallas kernel: blocked ELL SpMV.

TPU mapping (DESIGN.md §Hardware-Adaptation): rows are stored in ELL
(fixed width W, zero-padded) so each grid step streams one dense
(BR, W) tile of values/columns from HBM into VMEM — a regular access
pattern the VPU vectorizes, instead of the CSR gather loop a CPU code
would use. The dense x vector stays resident in VMEM across the grid
(one copy, reused by every row block).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated against ref.spmv_ell and real
TPU perf is estimated from the block geometry (EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row-block size: 8 sublanes × 16 = 128 rows keeps the value
# and column tiles at (128, W) — lane-aligned for f32.
DEFAULT_BLOCK_ROWS = 128


def _kernel(values_ref, cols_ref, x_ref, y_ref):
    """One (BR, W) row block: y = Σ_w values * x[cols]."""
    vals = values_ref[...]  # (BR, W) f32
    cols = cols_ref[...]  # (BR, W) i32
    x = x_ref[...]  # (N,) f32 — resident, shared by all blocks
    y_ref[...] = jnp.sum(vals * x[cols], axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def spmv_ell(values, cols, x, *, block_rows=DEFAULT_BLOCK_ROWS):
    """Pallas ELL SpMV. Shapes: values/cols (R, W) with R % block_rows
    == 0 (pad rows with zero-value entries), x (N,). Returns (R,)."""
    r, w = values.shape
    assert cols.shape == (r, w), f"cols {cols.shape} vs values {values.shape}"
    assert r % block_rows == 0, f"R={r} must be a multiple of {block_rows}"
    n = x.shape[0]
    grid = (r // block_rows,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),  # x: whole vector, every block
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float32),
        interpret=True,
    )(values, cols, x)


def csr_to_ell(rowptr, colidx, vals, width=None):
    """Convert CSR arrays (python lists / numpy) to zero-padded ELL.

    Returns (values, cols) with shape (R, W); rows longer than W are
    truncated (callers pick W = max nnz for exactness).
    """
    import numpy as np

    r = len(rowptr) - 1
    w = width or max((rowptr[i + 1] - rowptr[i] for i in range(r)), default=1)
    w = max(w, 1)
    values = np.zeros((r, w), dtype=np.float32)
    cols = np.zeros((r, w), dtype=np.int32)
    for i in range(r):
        lo, hi = rowptr[i], min(rowptr[i + 1], rowptr[i] + w)
        k = hi - lo
        values[i, :k] = vals[lo:hi]
        cols[i, :k] = colidx[lo:hi]
    return values, cols
