"""L2: the JAX compute graphs the Rust coordinator executes per chunk.

Each function composes the L1 Pallas kernels (which lower inline into
the same HLO). AOT shapes are fixed here (`AOT_SHAPES`) and recorded in
artifacts/manifest.json so the Rust runtime knows what to feed each
executable. Python runs only at `make artifacts` time.
"""

import jax.numpy as jnp

from .kernels import kmeans_assign as _km
from .kernels import lavamd_force as _lv
from .kernels import spmv_ell as _sp

# ---------------------------------------------------------------------------
# AOT shape contract (mirrored by rust/src/runtime/).
# ---------------------------------------------------------------------------
AOT_SHAPES = {
    # ELL SpMV chunk: 512 rows x width 16, x of length 8192.
    "spmv_ell": {"rows": 512, "width": 16, "n": 8192, "block_rows": 128},
    # K-Means assignment chunk: 1024 points x 34 features, 16 centroids.
    "kmeans_assign": {"points": 1024, "dim": 34, "k": 16, "block_points": 256},
    # LavaMD box: 64 home particles vs 27-neighborhood of 1728.
    "lavamd_force": {"home": 64, "neigh": 1728},
}


def spmv_ell(values, cols, x):
    """y = A x for one ELL row chunk (L1 kernel pass-through)."""
    shp = AOT_SHAPES["spmv_ell"]
    return (_sp.spmv_ell(values, cols, x, block_rows=shp["block_rows"]),)


def kmeans_assign(points, centroids):
    """Nearest-centroid assignment for one point chunk."""
    shp = AOT_SHAPES["kmeans_assign"]
    assign, dist2 = _km.kmeans_assign(points, centroids, block_points=shp["block_points"])
    return (assign, dist2)


def lavamd_force(home, neigh):
    """Per-box force accumulation."""
    return (_lv.lavamd_force(home, neigh),)


def example_args(name):
    """ShapeDtypeStructs for AOT lowering of model `name`."""
    import jax

    s = AOT_SHAPES[name]
    f32, i32 = jnp.float32, jnp.int32
    if name == "spmv_ell":
        return (
            jax.ShapeDtypeStruct((s["rows"], s["width"]), f32),
            jax.ShapeDtypeStruct((s["rows"], s["width"]), i32),
            jax.ShapeDtypeStruct((s["n"],), f32),
        )
    if name == "kmeans_assign":
        return (
            jax.ShapeDtypeStruct((s["points"], s["dim"]), f32),
            jax.ShapeDtypeStruct((s["k"], s["dim"]), f32),
        )
    if name == "lavamd_force":
        return (
            jax.ShapeDtypeStruct((s["home"], 4), f32),
            jax.ShapeDtypeStruct((s["neigh"], 4), f32),
        )
    raise KeyError(name)


MODELS = {
    "spmv_ell": spmv_ell,
    "kmeans_assign": kmeans_assign,
    "lavamd_force": lavamd_force,
}
