"""AOT lowering: JAX/Pallas (L2+L1) -> HLO text artifacts for the Rust
PJRT runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids, so text round-trips cleanly. Lowering uses
return_tuple=True; the Rust side unwraps with to_tuple().
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "models": {}}
    for name, fn in model.MODELS.items():
        args = model.example_args(name)
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["models"][name] = {
            "file": f"{name}.hlo.txt",
            "shapes": model.AOT_SHAPES[name],
            "inputs": [
                {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
